//! The R-worker pool: 𝒫 sockets plus sequence→socket placement
//! (paper §4.1 "different parts of them related to different sequences
//! are sent to the R-workers").
//!
//! Placement is round-robin at sequence granularity — R-Part has no
//! cross-sequence interaction, so any balanced assignment is work-
//! preserving; round-robin keeps per-socket total sequence length
//! balanced when combined with the SLS schedule (sequences of mixed ages
//! land on every socket).
//!
//! `RPool` is the in-process implementation of
//! [`AttendBackend`] — the same surface `crate::net::RemotePool`
//! provides over wire loopback or TCP. A dead socket thread surfaces as
//! a routed error carrying its panic payload ([`RWorker::recv`]); on a
//! mid-gather failure the surviving sockets are still drained so the
//! pool stays reusable for the sequences they hold.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::model::{ModelSpec, Precision};
use crate::obs::{Tracer, Track};

pub use super::backend::{AttendBackend, PendingAttend, PoolStep};
use super::worker::{RRequest, RResponse, RWorker, SeqTask};

#[derive(Clone, Copy, Debug)]
pub struct RPoolConfig {
    pub sockets: usize,
    pub capacity_per_seq: usize,
    /// Tokens per KV block (paged allocation; kvcache::BlockPool).
    pub block_size: usize,
    pub precision: Precision,
    /// Artificial dilation per appended token row of every attend (a
    /// decode task is one row, a prefill task is T rows), applied
    /// inside every socket and counted in its busy time. Zero in
    /// production; pipeline smoke/depth tests use it to pin the R-stage
    /// latency (see `RWorker::spawn`).
    pub attend_pad: Duration,
}

impl Default for RPoolConfig {
    fn default() -> Self {
        RPoolConfig {
            sockets: 2,
            capacity_per_seq: 2048,
            block_size: 16,
            precision: Precision::F16,
            attend_pad: Duration::ZERO,
        }
    }
}

pub struct RPool {
    workers: Vec<RWorker>,
    /// BTreeMap, not HashMap: whole-map walks see ascending seq ids, so
    /// anything derived from placement order stays deterministic
    /// (bit-identity pins).
    placement: BTreeMap<u64, usize>,
    next_socket: usize,
    /// One trace track per socket (all disabled until `install_tracer`).
    tracks: Vec<Track>,
}

impl RPool {
    pub fn spawn(spec: &ModelSpec, cfg: RPoolConfig) -> RPool {
        assert!(cfg.sockets > 0);
        let workers = (0..cfg.sockets)
            .map(|i| {
                RWorker::spawn(
                    i,
                    spec.n_heads,
                    spec.head_dim(),
                    spec.n_layers,
                    cfg.capacity_per_seq,
                    cfg.block_size,
                    cfg.precision,
                    cfg.attend_pad,
                )
            })
            .collect();
        RPool {
            workers,
            placement: BTreeMap::new(),
            next_socket: 0,
            tracks: Vec::new(),
        }
    }

    /// Create one trace track per socket; each gathered attend then
    /// records a submit→reply span on its socket's track.
    pub fn install_tracer(&mut self, tracer: Tracer) {
        self.tracks = (0..self.workers.len())
            .map(|i| tracer.track(&format!("r-socket{i}")))
            .collect();
    }

    pub fn sockets(&self) -> usize {
        self.workers.len()
    }

    pub fn socket_of(&self, seq_id: u64) -> Option<usize> {
        self.placement.get(&seq_id).copied()
    }

    /// Fault-injection hook: shut socket `s` down so its thread exits
    /// with the pool still holding placements on it — the next request
    /// touching it surfaces a disconnect error. Used by the killed-node
    /// regression tests; production code never calls it.
    pub fn kill_socket_for_test(&mut self, s: usize) {
        let _ = self.workers[s].submit(RRequest::Shutdown);
    }

    /// Place and register new sequences (round-robin). All-or-nothing:
    /// the placement map is committed only after EVERY socket acked its
    /// group — a mid-loop socket failure rolls the acked sockets back
    /// (best effort), so no sequence is ever locally "placed" on a
    /// socket that never registered it, and the pool stays usable.
    pub fn add_seqs(&mut self, seq_ids: &[u64]) -> Result<()> {
        // fdlint: allow(deterministic-iteration): membership-only duplicate check, never iterated
        let mut seen = std::collections::HashSet::with_capacity(seq_ids.len());
        let mut per_socket: Vec<Vec<u64>> = vec![vec![]; self.workers.len()];
        for &id in seq_ids {
            assert!(
                !self.placement.contains_key(&id) && seen.insert(id),
                "sequence {id} already placed"
            );
            let s = self.next_socket;
            self.next_socket = (self.next_socket + 1) % self.workers.len();
            per_socket[s].push(id);
        }
        let mut acked: Vec<usize> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for (s, ids) in per_socket.iter().enumerate() {
            if ids.is_empty() {
                continue;
            }
            let res = (|| -> Result<()> {
                self.workers[s].submit(RRequest::AddSeqs(ids.clone()))?;
                match self.workers[s].recv()? {
                    RResponse::Ack => Ok(()),
                    _ => bail!("expected ack from socket {s}"),
                }
            })();
            match res {
                Ok(()) => acked.push(s),
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            for s in acked {
                // roll back so the registration is all-or-nothing
                if self
                    .workers[s]
                    .submit(RRequest::DropSeqs(per_socket[s].clone()))
                    .is_ok()
                {
                    let _ = self.workers[s].recv();
                }
            }
            return Err(e);
        }
        for (s, ids) in per_socket.into_iter().enumerate() {
            for id in ids {
                self.placement.insert(id, s);
            }
        }
        Ok(())
    }

    /// Drop finished sequences and free their cache. Sequences placed
    /// on a DEAD socket are unplaced locally without error — their
    /// cache died with the socket, and retiring them is exactly how a
    /// caller makes the pool reusable after a socket failure.
    pub fn drop_seqs(&mut self, seq_ids: &[u64]) -> Result<()> {
        let mut per_socket: Vec<Vec<u64>> = vec![vec![]; self.workers.len()];
        for &id in seq_ids {
            if let Some(s) = self.placement.remove(&id) {
                per_socket[s].push(id);
            }
        }
        for (s, ids) in per_socket.into_iter().enumerate() {
            if ids.is_empty() {
                continue;
            }
            if self.workers[s].submit(RRequest::DropSeqs(ids)).is_err() {
                continue; // dead socket: placement removal is the effect
            }
            match self.workers[s].recv() {
                Ok(RResponse::Ack) => {}
                Ok(_) => bail!("expected ack from socket {s}"),
                Err(_) => continue, // died mid-drop: same as above
            }
        }
        Ok(())
    }

    /// COW-fork `child` off `parent`'s first `upto` tokens. The child
    /// lands on the parent's socket — shared blocks live in one cache —
    /// so fork placement overrides round-robin.
    pub fn fork_seq(
        &mut self,
        parent: u64,
        child: u64,
        upto: usize,
    ) -> Result<()> {
        let s = match self.placement.get(&parent) {
            Some(&s) => s,
            None => bail!("sequence {parent} not placed"),
        };
        assert!(
            !self.placement.contains_key(&child),
            "sequence {child} already placed"
        );
        self.workers[s].submit(RRequest::ForkSeq {
            parent,
            child,
            upto,
        })?;
        match self.workers[s].recv()? {
            RResponse::Ack => {}
            _ => bail!("expected ack from socket {s}"),
        }
        self.placement.insert(child, s);
        Ok(())
    }

    /// Scatter one layer's tasks to their sockets WITHOUT waiting for
    /// the results — the sockets start computing immediately, and the
    /// caller is free to do S-Part work for the other mini-batch before
    /// calling [`RPool::wait_attend`]. This split is what the threaded
    /// token-level pipeline (Fig 5b) is built on.
    ///
    /// At most one task per sequence per call: outputs are keyed by
    /// `seq_id`, so a duplicate would silently collapse — `wait_attend`
    /// counts outputs against tasks and panics if that happens. Multi-
    /// token work for one sequence travels as ONE multi-row task (see
    /// [`SeqTask`]).
    ///
    /// On error (unplaced sequence, dead socket) the sockets that were
    /// already handed tasks are drained before returning, so no stale
    /// reply can cross into the next attend.
    pub fn submit_attend(
        &mut self,
        layer: usize,
        tasks: Vec<SeqTask>,
    ) -> Result<PendingAttend> {
        let n = tasks.len();
        let mut per_socket: Vec<Vec<SeqTask>> =
            (0..self.workers.len()).map(|_| Vec::new()).collect();
        for task in tasks {
            match self.placement.get(&task.seq_id) {
                Some(&s) => per_socket[s].push(task),
                None => bail!("sequence {} not placed", task.seq_id),
            }
        }
        let mut active = Vec::new();
        for (s, tasks) in per_socket.into_iter().enumerate() {
            if tasks.is_empty() {
                continue;
            }
            if let Err(e) = self.workers[s].submit(RRequest::Attend {
                layer,
                tasks,
            }) {
                // drain what was already scattered, then surface the
                // root cause
                for &a in &active {
                    let _ = self.workers[a].recv();
                }
                return Err(e);
            }
            active.push(s);
        }
        Ok(PendingAttend {
            active,
            layer,
            n,
            submitted: Instant::now(),
        })
    }

    /// Gather one in-flight attend. Replies are FIFO per socket, so
    /// pending handles must be waited in submission order; the echoed
    /// layer tag and output count turn an out-of-order wait into a
    /// panic instead of silently crossed activations. A dead socket
    /// surfaces as an error AFTER the surviving sockets are drained, so
    /// the pool stays in sync for the next step.
    pub fn wait_attend(&mut self, pending: PendingAttend) -> Result<PoolStep> {
        let mut outputs = BTreeMap::new();
        let mut max_busy = Duration::ZERO;
        let mut total_busy = Duration::ZERO;
        let mut socket_busy: Vec<(usize, Duration)> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for s in pending.active {
            match self.workers[s].recv() {
                Ok(RResponse::Outputs { layer, outs, busy }) => {
                    assert_eq!(
                        layer, pending.layer,
                        "socket {s} replied for layer {layer}, \
                         handle is for layer {}: attends gathered out \
                         of submission order",
                        pending.layer
                    );
                    max_busy = max_busy.max(busy);
                    total_busy += busy;
                    socket_busy.push((s, busy));
                    if let Some(track) = self.tracks.get(s) {
                        track.record(
                            "attend",
                            pending.submitted,
                            Instant::now(),
                            &[
                                ("socket", s as f64),
                                ("layer", pending.layer as f64),
                                ("busy_us", busy.as_secs_f64() * 1e6),
                            ],
                        );
                    }
                    for (id, o) in outs {
                        outputs.insert(id, o);
                    }
                }
                Ok(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!(
                            "expected outputs from socket {s}"
                        ));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        assert_eq!(
            outputs.len(),
            pending.n,
            "attend returned {} outputs for {} tasks",
            outputs.len(),
            pending.n
        );
        Ok(PoolStep {
            outputs,
            max_busy,
            total_busy,
            socket_busy,
        })
    }

    /// Scatter one layer's tasks to sockets, attend in parallel, gather.
    ///
    /// All sockets compute concurrently; the returned `max_busy` is what
    /// the token-level pipeline sees as R-Part latency (Fig 15's
    /// "performance variance across nodes makes some workers wait").
    pub fn attend(
        &mut self,
        layer: usize,
        tasks: Vec<SeqTask>,
    ) -> Result<PoolStep> {
        let pending = self.submit_attend(layer, tasks)?;
        self.wait_attend(pending)
    }

    /// Aggregate cache statistics across sockets.
    pub fn stats(&mut self) -> Result<Vec<crate::kvcache::CacheStats>> {
        let mut all = Vec::new();
        for w in &mut self.workers {
            w.submit(RRequest::Stats)?;
            match w.recv()? {
                RResponse::Stats(st) => all.push(st),
                _ => bail!("expected stats"),
            }
        }
        Ok(all)
    }
}

impl AttendBackend for RPool {
    fn name(&self) -> &'static str {
        "rpool-threads"
    }
    fn sockets(&self) -> usize {
        RPool::sockets(self)
    }
    fn socket_of(&self, seq_id: u64) -> Option<usize> {
        RPool::socket_of(self, seq_id)
    }
    fn add_seqs(&mut self, seq_ids: &[u64]) -> Result<()> {
        RPool::add_seqs(self, seq_ids)
    }
    fn drop_seqs(&mut self, seq_ids: &[u64]) -> Result<()> {
        RPool::drop_seqs(self, seq_ids)
    }
    fn fork_seq(
        &mut self,
        parent: u64,
        child: u64,
        upto: usize,
    ) -> Result<()> {
        RPool::fork_seq(self, parent, child, upto)
    }
    fn submit_attend(
        &mut self,
        layer: usize,
        tasks: Vec<SeqTask>,
    ) -> Result<PendingAttend> {
        RPool::submit_attend(self, layer, tasks)
    }
    fn wait_attend(&mut self, pending: PendingAttend) -> Result<PoolStep> {
        RPool::wait_attend(self, pending)
    }
    fn stats(&mut self) -> Result<Vec<crate::kvcache::CacheStats>> {
        RPool::stats(self)
    }
    fn install_tracer(&mut self, tracer: Tracer) {
        RPool::install_tracer(self, tracer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TINY;
    use crate::util::Rng;

    fn mk_task(rng: &mut Rng, id: u64, n: usize) -> SeqTask {
        SeqTask {
            seq_id: id,
            q: rng.normal_vec(n, 1.0),
            k_new: rng.normal_vec(n, 1.0),
            v_new: rng.normal_vec(n, 1.0),
        }
    }

    #[test]
    fn round_robin_placement_balances() {
        let mut pool = RPool::spawn(
            &TINY,
            RPoolConfig {
                sockets: 3,
                capacity_per_seq: 8,
                precision: Precision::F32,
                ..Default::default()
            },
        );
        pool.add_seqs(&[0, 1, 2, 3, 4, 5]).unwrap();
        let mut counts = [0usize; 3];
        for id in 0..6u64 {
            counts[pool.socket_of(id).unwrap()] += 1;
        }
        assert_eq!(counts, [2, 2, 2]);
    }

    /// The deterministic-iteration discipline, pinned: placement and
    /// gathered outputs walk in ascending seq-id order (BTreeMap), while
    /// round-robin assignment still follows insertion order.
    #[test]
    fn placement_and_outputs_iterate_in_seq_id_order() {
        let mut pool = RPool::spawn(
            &TINY,
            RPoolConfig {
                sockets: 2,
                capacity_per_seq: 8,
                precision: Precision::F32,
                ..Default::default()
            },
        );
        // insertion order deliberately shuffled
        pool.add_seqs(&[9, 2, 7, 1, 4]).unwrap();
        let ids: Vec<u64> = pool.placement.keys().copied().collect();
        assert_eq!(ids, vec![1, 2, 4, 7, 9], "placement walk not sorted");
        assert_eq!(pool.socket_of(9), Some(0), "round-robin order changed");
        assert_eq!(pool.socket_of(2), Some(1), "round-robin order changed");
        let mut rng = Rng::new(11);
        let tasks: Vec<SeqTask> = [9u64, 2, 7, 1, 4]
            .iter()
            .map(|&i| mk_task(&mut rng, i, TINY.hidden))
            .collect();
        let step = pool.attend(0, tasks).unwrap();
        let out_ids: Vec<u64> = step.outputs.keys().copied().collect();
        assert_eq!(out_ids, vec![1, 2, 4, 7, 9], "outputs walk not sorted");
    }

    #[test]
    fn scatter_gather_matches_single_socket() {
        // Same tasks through 1 socket and 3 sockets must agree exactly.
        let n = TINY.hidden;
        let run = |sockets: usize| {
            let mut pool = RPool::spawn(
                &TINY,
                RPoolConfig {
                    sockets,
                    capacity_per_seq: 8,
                    precision: Precision::F32,
                    ..Default::default()
                },
            );
            let ids: Vec<u64> = (0..5).collect();
            pool.add_seqs(&ids).unwrap();
            let mut rng = Rng::new(42);
            let mut last = BTreeMap::new();
            for _ in 0..3 {
                let tasks: Vec<SeqTask> =
                    ids.iter().map(|&i| mk_task(&mut rng, i, n)).collect();
                last = pool.attend(0, tasks).unwrap().outputs;
            }
            last
        };
        let one = run(1);
        let three = run(3);
        assert_eq!(one.len(), three.len());
        for (id, o1) in &one {
            let o3 = &three[id];
            for (a, b) in o1.iter().zip(o3) {
                assert_eq!(a, b, "seq {id} diverged across pool sizes");
            }
        }
    }

    #[test]
    fn drop_frees_cache() {
        let mut pool = RPool::spawn(
            &TINY,
            RPoolConfig {
                sockets: 2,
                capacity_per_seq: 8,
                precision: Precision::F16,
                ..Default::default()
            },
        );
        pool.add_seqs(&[1, 2, 3, 4]).unwrap();
        let before: usize =
            pool.stats().unwrap().iter().map(|s| s.sequences).sum();
        assert_eq!(before, 4);
        pool.drop_seqs(&[2, 3]).unwrap();
        let after: usize =
            pool.stats().unwrap().iter().map(|s| s.sequences).sum();
        assert_eq!(after, 2);
        assert_eq!(pool.socket_of(2), None);
    }

    /// fork_seq co-locates the child with its parent (not round-robin)
    /// and the forked prefix yields bit-identical attention: a decode
    /// step on the child matches the same step on a sequence that
    /// appended the prefix itself.
    #[test]
    fn fork_colocates_and_matches_self_appended() {
        let n = TINY.hidden;
        let mut rng = Rng::new(8);
        let prefix: Vec<SeqTask> =
            (0..3).map(|_| mk_task(&mut rng, 0, n)).collect();
        let probe = mk_task(&mut rng, 0, n);

        let mut pool = RPool::spawn(
            &TINY,
            RPoolConfig {
                sockets: 2,
                capacity_per_seq: 8,
                block_size: 2,
                precision: Precision::F32,
                ..Default::default()
            },
        );
        // seq 0 → socket 0, seq 1 → socket 1
        pool.add_seqs(&[0, 1]).unwrap();
        for t in &prefix {
            // feed EVERY layer so each reaches the fork point
            for layer in 0..TINY.n_layers {
                let both =
                    vec![t.clone(), SeqTask { seq_id: 1, ..t.clone() }];
                pool.attend(layer, both).unwrap();
            }
        }
        // fork child 7 off seq 0's full 3-token prefix
        pool.fork_seq(0, 7, 3).unwrap();
        assert_eq!(pool.socket_of(7), pool.socket_of(0));
        // the probe on the child matches the probe on seq 1, which
        // appended the identical prefix itself on another socket
        let out = pool
            .attend(
                0,
                vec![
                    SeqTask { seq_id: 7, ..probe.clone() },
                    SeqTask { seq_id: 1, ..probe.clone() },
                ],
            )
            .unwrap()
            .outputs;
        assert_eq!(out[&7], out[&1], "forked prefix diverged");
    }

    #[test]
    fn attend_unplaced_is_routed_error() {
        let mut pool = RPool::spawn(&TINY, RPoolConfig::default());
        let mut rng = Rng::new(1);
        let err = pool
            .attend(0, vec![mk_task(&mut rng, 99, TINY.hidden)])
            .unwrap_err();
        assert!(format!("{err:#}").contains("not placed"), "{err:#}");
    }

    /// Killed-node regression (in-proc backend): a socket that dies
    /// with placements still on it surfaces a routed error on the next
    /// attend — not a hang — and the surviving socket keeps serving its
    /// own sequences through the SAME pool.
    #[test]
    fn killed_socket_errors_and_pool_survives() {
        let n = TINY.hidden;
        let mut pool = RPool::spawn(
            &TINY,
            RPoolConfig {
                sockets: 2,
                capacity_per_seq: 8,
                precision: Precision::F32,
                ..Default::default()
            },
        );
        // round-robin: 0,2 → socket 0; 1,3 → socket 1
        pool.add_seqs(&[0, 1, 2, 3]).unwrap();
        let mut rng = Rng::new(7);
        pool.kill_socket_for_test(0);
        let tasks: Vec<SeqTask> =
            (0..4).map(|i| mk_task(&mut rng, i, n)).collect();
        let err = pool.attend(0, tasks).unwrap_err();
        assert!(format!("{err:#}").contains("died"), "{err:#}");
        // sequences on the dead socket drop locally; the survivor still
        // attends its own (the failed gather drained it, so replies
        // cannot cross)
        pool.drop_seqs(&[0, 2]).unwrap();
        let step = pool
            .attend(0, vec![mk_task(&mut rng, 1, n), mk_task(&mut rng, 3, n)])
            .unwrap();
        assert_eq!(step.outputs.len(), 2);
    }
}
