//! One R-worker socket: a thread owning a SocketCache, serving
//! append+attend requests over channels (paper §4.1's R-worker loop).

use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::kvcache::{CacheStats, SocketCache};
use crate::model::Precision;
use crate::util::chan::{bounded, Receiver, Sender};

use super::attention::{attend_paged, AttnScratch};

/// Per-sequence work item within one step: the activation vectors of
/// the newest token(s) — the only data FastDecode ships across the
/// interconnect.
///
/// A decode task carries one token (T = 1). A batched-prefill task
/// carries T consecutive positions of the SAME sequence: the worker
/// appends and attends them in row order, so row p sees exactly
/// positions 0..=p of the cache — a causal multi-token prefill in one
/// round trip. At most one task per sequence may appear in a single
/// `Attend` request (outputs are keyed by `seq_id`).
#[derive(Clone, Debug, PartialEq)]
pub struct SeqTask {
    pub seq_id: u64,
    /// `[T * H * D]` each, row-major over T positions, head-major
    /// within a row.
    pub q: Vec<f32>,
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
}

/// A request to one socket.
pub enum RRequest {
    /// Register sequences before first use.
    AddSeqs(Vec<u64>),
    /// Drop finished sequences.
    DropSeqs(Vec<u64>),
    /// COW-fork `child` off `parent`'s first `upto` tokens (all layers):
    /// the child references the parent's blocks, no copy (paper-adjacent
    /// prefix sharing; kvcache::SocketCache::fork_seq).
    ForkSeq { parent: u64, child: u64, upto: usize },
    /// Append K/V and compute attention for one layer of one micro-batch.
    Attend { layer: usize, tasks: Vec<SeqTask> },
    /// Report cache statistics.
    Stats,
    Shutdown,
}

/// Socket → coordinator reply.
pub enum RResponse {
    /// Outputs in task order: (seq_id, o `[H*D]`), plus busy time spent.
    /// Echoes the request's `layer` so out-of-order gathers fail loudly
    /// instead of silently crossing activations between layers.
    Outputs {
        layer: usize,
        outs: Vec<(u64, Vec<f32>)>,
        busy: std::time::Duration,
    },
    Stats(CacheStats),
    Ack,
}

/// Handle to a spawned R-worker socket thread.
pub struct RWorker {
    pub socket_id: usize,
    tx: Sender<RRequest>,
    rx: Receiver<RResponse>,
    handle: Option<JoinHandle<()>>,
}

impl RWorker {
    /// `attend_pad` artificially dilates every Attend by a sleep of
    /// `pad × rows` — per appended token row (a decode task is one row,
    /// a prefill task is T rows), so the total dilation of a step is
    /// invariant to how the batch is split into mini-batches (counted
    /// in the reported busy time). Zero in production; the pipeline
    /// smoke/depth tests use it to pin the R-stage latency so the
    /// max(s, r)-vs-(s + r) assertion is robust on any machine.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        socket_id: usize,
        n_heads: usize,
        head_dim: usize,
        n_layers: usize,
        capacity_per_seq: usize,
        block_size: usize,
        prec: Precision,
        attend_pad: Duration,
    ) -> RWorker {
        let (req_tx, req_rx) = bounded::<RRequest>(4);
        let (resp_tx, resp_rx) = bounded::<RResponse>(4);
        let handle = std::thread::Builder::new()
            .name(format!("rworker-{socket_id}"))
            .spawn(move || {
                run_loop(
                    req_rx,
                    resp_tx,
                    SocketCache::new(
                        n_heads,
                        head_dim,
                        n_layers,
                        capacity_per_seq,
                        block_size,
                        prec,
                    ),
                    head_dim,
                    attend_pad,
                )
            })
            // fdlint: allow(no-unwrap-in-routed): thread spawn fails only on OS resource exhaustion, before any request is accepted
            .expect("spawning rworker thread");
        RWorker {
            socket_id,
            tx: req_tx,
            rx: resp_rx,
            handle: Some(handle),
        }
    }

    /// Fire a request (does not wait for the reply). Fails — with the
    /// worker's panic payload as the root cause — if the socket thread
    /// has died.
    pub fn submit(&mut self, req: RRequest) -> Result<()> {
        if self.tx.send(req).is_err() {
            let cause = self.death_cause();
            return Err(anyhow!(
                "r-worker socket {} died: {cause}",
                self.socket_id
            ));
        }
        Ok(())
    }

    /// Wait for the next reply. A dead peer surfaces as an error
    /// carrying the root cause (the thread's panic payload), never as
    /// a hang or a bare "thread died": the worker drops its response
    /// sender on ANY exit path, so a disconnect is always observable.
    pub fn recv(&mut self) -> Result<RResponse> {
        match self.rx.recv() {
            Ok(resp) => Ok(resp),
            Err(_) => {
                let cause = self.death_cause();
                Err(anyhow!(
                    "r-worker socket {} died: {cause}",
                    self.socket_id
                ))
            }
        }
    }

    /// Reap the dead thread and extract why it exited. Joining here is
    /// safe: the response channel only disconnects once the thread body
    /// has returned or begun unwinding.
    fn death_cause(&mut self) -> String {
        match self.handle.take() {
            Some(h) => match h.join() {
                Ok(()) => "worker exited (shutdown) with requests \
                           outstanding"
                    .to_string(),
                Err(payload) => payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| {
                        payload.downcast_ref::<&str>().map(|s| s.to_string())
                    })
                    .unwrap_or_else(|| "worker panicked".to_string()),
            },
            None => "worker already reaped".to_string(),
        }
    }
}

impl Drop for RWorker {
    fn drop(&mut self) {
        let _ = self.tx.send(RRequest::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn run_loop(
    rx: Receiver<RRequest>,
    tx: Sender<RResponse>,
    mut cache: SocketCache,
    head_dim: usize,
    attend_pad: Duration,
) {
    let mut scratch = AttnScratch::new(head_dim);
    while let Ok(req) = rx.recv() {
        match req {
            RRequest::AddSeqs(ids) => {
                for id in ids {
                    cache.add_seq(id);
                }
                let _ = tx.send(RResponse::Ack);
            }
            RRequest::DropSeqs(ids) => {
                for id in ids {
                    cache.drop_seq(id);
                }
                let _ = tx.send(RResponse::Ack);
            }
            RRequest::Attend { layer, tasks } => {
                let start = std::time::Instant::now();
                let mut outs = Vec::with_capacity(tasks.len());
                let mut total_rows = 0usize;
                let width = cache.n_heads * cache.head_dim;
                for task in &tasks {
                    // in-process discipline: a bad request kills the
                    // worker (the pool surfaces the panic payload);
                    // rnode's TCP front validates and routes instead
                    // fdlint: allow(no-unwrap-in-routed): in-process discipline — the panic payload becomes the pool's routed error (see module docs)
                    let len = cache.seq_len(task.seq_id, layer).unwrap();
                    assert!(
                        !task.q.is_empty()
                            && task.q.len() % width == 0
                            && task.k_new.len() == task.q.len()
                            && task.v_new.len() == task.q.len(),
                        "seq {}: malformed task (q {} k {} v {}, width {width})",
                        task.seq_id,
                        task.q.len(),
                        task.k_new.len(),
                        task.v_new.len(),
                    );
                    let rows = task.q.len() / width;
                    assert!(
                        rows <= cache.capacity_per_seq - len,
                        "seq {}: {rows}-row prefill overflows KV cache \
                         ({} of {} slots used)",
                        task.seq_id,
                        len,
                        cache.capacity_per_seq,
                    );
                    let mut o = vec![0.0f32; task.q.len()];
                    // append+attend row by row: row p attends positions
                    // 0..=p — causal prefill (T > 1) and plain decode
                    // (T = 1) are the same loop
                    for r in 0..rows {
                        let s = r * width..(r + 1) * width;
                        cache
                            .append(
                                task.seq_id,
                                layer,
                                &task.k_new[s.clone()],
                                &task.v_new[s.clone()],
                            )
                            // fdlint: allow(no-unwrap-in-routed): in-process discipline — panic payload becomes the pool's routed error
                            .unwrap();
                        attend_paged(
                            // fdlint: allow(no-unwrap-in-routed): same in-process discipline as the append above
                            &cache.get(task.seq_id, layer).unwrap(),
                            &task.q[s.clone()],
                            &mut o[s.clone()],
                            &mut scratch,
                        );
                    }
                    total_rows += rows;
                    outs.push((task.seq_id, o));
                }
                // pad is charged PER ROW so a step's total dilation is
                // invariant to how rows are split into mini-batches
                if !attend_pad.is_zero() && total_rows > 0 {
                    std::thread::sleep(attend_pad * total_rows as u32);
                }
                let busy = start.elapsed();
                if tx.send(RResponse::Outputs { layer, outs, busy }).is_err() {
                    return;
                }
            }
            RRequest::ForkSeq { parent, child, upto } => {
                // fdlint: allow(no-unwrap-in-routed): in-process discipline — panic payload becomes the pool's routed error
                cache.fork_seq(parent, child, upto).unwrap();
                let _ = tx.send(RResponse::Ack);
            }
            RRequest::Stats => {
                let _ = tx.send(RResponse::Stats(cache.stats()));
            }
            RRequest::Shutdown => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn worker_appends_and_attends() {
        let (h, d) = (2, 4);
        let mut w = RWorker::spawn(
            0,
            h,
            d,
            1,
            16,
            4,
            Precision::F32,
            Duration::ZERO,
        );
        w.submit(RRequest::AddSeqs(vec![1, 2])).unwrap();
        assert!(matches!(w.recv().unwrap(), RResponse::Ack));

        let mut rng = Rng::new(3);
        let mk = |rng: &mut Rng, id| SeqTask {
            seq_id: id,
            q: rng.normal_vec(h * d, 1.0),
            k_new: rng.normal_vec(h * d, 1.0),
            v_new: rng.normal_vec(h * d, 1.0),
        };
        let t1 = mk(&mut rng, 1);
        let v1 = t1.v_new.clone();
        w.submit(RRequest::Attend {
            layer: 0,
            tasks: vec![t1, mk(&mut rng, 2)],
        })
        .unwrap();
        match w.recv().unwrap() {
            RResponse::Outputs { outs, .. } => {
                assert_eq!(outs.len(), 2);
                assert_eq!(outs[0].0, 1);
                // first token ⇒ o == v_new exactly (f32 cache)
                for (a, b) in outs[0].1.iter().zip(&v1) {
                    assert!((a - b).abs() < 1e-6);
                }
            }
            _ => panic!("expected outputs"),
        }

        w.submit(RRequest::Stats).unwrap();
        match w.recv().unwrap() {
            RResponse::Stats(st) => {
                assert_eq!(st.sequences, 2);
                assert_eq!(st.total_tokens, 2);
            }
            _ => panic!("expected stats"),
        }

        w.submit(RRequest::DropSeqs(vec![1])).unwrap();
        assert!(matches!(w.recv().unwrap(), RResponse::Ack));
        w.submit(RRequest::Stats).unwrap();
        match w.recv().unwrap() {
            RResponse::Stats(st) => assert_eq!(st.sequences, 1),
            _ => panic!(),
        }
    }

    /// A T-row prefill task is bit-identical to feeding the same T
    /// positions as T single-row attends: same cache state, and the
    /// multi-row outputs equal the concatenated single-row outputs.
    #[test]
    fn multi_row_prefill_equals_token_at_a_time() {
        let (h, d, t_rows) = (2usize, 4usize, 5usize);
        let width = h * d;
        let mut rng = Rng::new(9);
        let q: Vec<f32> = rng.normal_vec(t_rows * width, 1.0);
        let k: Vec<f32> = rng.normal_vec(t_rows * width, 1.0);
        let v: Vec<f32> = rng.normal_vec(t_rows * width, 1.0);
        let probe_q = rng.normal_vec(width, 1.0);
        let probe_k = rng.normal_vec(width, 1.0);
        let probe_v = rng.normal_vec(width, 1.0);

        let run = |multi: bool| -> (Vec<f32>, Vec<f32>) {
            let mut w = RWorker::spawn(
                0,
                h,
                d,
                1,
                16,
                4,
                Precision::F32,
                Duration::ZERO,
            );
            w.submit(RRequest::AddSeqs(vec![1])).unwrap();
            assert!(matches!(w.recv().unwrap(), RResponse::Ack));
            let mut prefill_out = Vec::new();
            if multi {
                w.submit(RRequest::Attend {
                    layer: 0,
                    tasks: vec![SeqTask {
                        seq_id: 1,
                        q: q.clone(),
                        k_new: k.clone(),
                        v_new: v.clone(),
                    }],
                })
                .unwrap();
                match w.recv().unwrap() {
                    RResponse::Outputs { outs, .. } => {
                        prefill_out = outs[0].1.clone()
                    }
                    _ => panic!("expected outputs"),
                }
            } else {
                for r in 0..t_rows {
                    let s = r * width..(r + 1) * width;
                    w.submit(RRequest::Attend {
                        layer: 0,
                        tasks: vec![SeqTask {
                            seq_id: 1,
                            q: q[s.clone()].to_vec(),
                            k_new: k[s.clone()].to_vec(),
                            v_new: v[s.clone()].to_vec(),
                        }],
                    })
                    .unwrap();
                    match w.recv().unwrap() {
                        RResponse::Outputs { outs, .. } => {
                            prefill_out.extend_from_slice(&outs[0].1)
                        }
                        _ => panic!("expected outputs"),
                    }
                }
            }
            // a probe decode step proves the cache state is identical
            w.submit(RRequest::Attend {
                layer: 0,
                tasks: vec![SeqTask {
                    seq_id: 1,
                    q: probe_q.clone(),
                    k_new: probe_k.clone(),
                    v_new: probe_v.clone(),
                }],
            })
            .unwrap();
            let probe_out = match w.recv().unwrap() {
                RResponse::Outputs { outs, .. } => outs[0].1.clone(),
                _ => panic!("expected outputs"),
            };
            (prefill_out, probe_out)
        };
        let (multi_o, multi_probe) = run(true);
        let (single_o, single_probe) = run(false);
        assert_eq!(multi_o, single_o, "prefill outputs diverged");
        assert_eq!(multi_probe, single_probe, "cache state diverged");
    }

    /// A multi-row task that would overflow the per-sequence capacity
    /// kills the worker on the guard assertion (before any append
    /// lands). Regression (killed-peer discipline): the next `recv`
    /// must return an error CARRYING the guard's message as the root
    /// cause — not hang, not panic with a bare "thread died".
    #[test]
    fn multi_row_overflow_surfaces_root_cause() {
        let (h, d) = (1usize, 4usize);
        let mut w = RWorker::spawn(
            0,
            h,
            d,
            1,
            4,
            2,
            Precision::F32,
            Duration::ZERO,
        );
        w.submit(RRequest::AddSeqs(vec![1])).unwrap();
        assert!(matches!(w.recv().unwrap(), RResponse::Ack));
        let mut rng = Rng::new(2);
        let rows = 5; // capacity is 4
        w.submit(RRequest::Attend {
            layer: 0,
            tasks: vec![SeqTask {
                seq_id: 1,
                q: rng.normal_vec(rows * h * d, 1.0),
                k_new: rng.normal_vec(rows * h * d, 1.0),
                v_new: rng.normal_vec(rows * h * d, 1.0),
            }],
        })
        .unwrap();
        let err = w.recv().unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("overflows KV cache"),
            "disconnect lost the root cause: {msg}"
        );
        // a second use keeps erroring instead of hanging
        let err2 = w.submit(RRequest::Stats).unwrap_err();
        assert!(format!("{err2:#}").contains("died"), "{err2:#}");
    }

    /// ForkSeq makes the child share the parent's prefix blocks: the
    /// stats show logical tokens exceeding physical tokens.
    #[test]
    fn fork_seq_shares_blocks_on_the_worker() {
        let (h, d) = (1usize, 4usize);
        let mut w = RWorker::spawn(
            0,
            h,
            d,
            1,
            16,
            2,
            Precision::F32,
            Duration::ZERO,
        );
        w.submit(RRequest::AddSeqs(vec![1])).unwrap();
        assert!(matches!(w.recv().unwrap(), RResponse::Ack));
        let mut rng = Rng::new(6);
        for _ in 0..4 {
            w.submit(RRequest::Attend {
                layer: 0,
                tasks: vec![SeqTask {
                    seq_id: 1,
                    q: rng.normal_vec(h * d, 1.0),
                    k_new: rng.normal_vec(h * d, 1.0),
                    v_new: rng.normal_vec(h * d, 1.0),
                }],
            })
            .unwrap();
            w.recv().unwrap();
        }
        w.submit(RRequest::ForkSeq {
            parent: 1,
            child: 2,
            upto: 4,
        })
        .unwrap();
        assert!(matches!(w.recv().unwrap(), RResponse::Ack));
        w.submit(RRequest::Stats).unwrap();
        match w.recv().unwrap() {
            RResponse::Stats(st) => {
                assert_eq!(st.sequences, 2);
                assert_eq!(st.total_tokens, 8); // 4 logical each
                assert_eq!(st.physical_tokens, 4); // stored once
                assert!(st.utilization() > 1.0, "{st:?}");
            }
            _ => panic!("expected stats"),
        }
        // the child keeps serving attends (COW past the fork point)
        w.submit(RRequest::Attend {
            layer: 0,
            tasks: vec![SeqTask {
                seq_id: 2,
                q: rng.normal_vec(h * d, 1.0),
                k_new: rng.normal_vec(h * d, 1.0),
                v_new: rng.normal_vec(h * d, 1.0),
            }],
        })
        .unwrap();
        match w.recv().unwrap() {
            RResponse::Outputs { outs, .. } => {
                assert!(outs[0].1.iter().all(|x| x.is_finite()));
            }
            _ => panic!("expected outputs"),
        }
    }

    #[test]
    fn growing_sequence_is_consistent() {
        let (h, d) = (1, 8);
        let mut w = RWorker::spawn(
            0,
            h,
            d,
            2,
            32,
            8,
            Precision::F16,
            Duration::ZERO,
        );
        w.submit(RRequest::AddSeqs(vec![7])).unwrap();
        w.recv().unwrap();
        let mut rng = Rng::new(4);
        for step in 0..10 {
            for layer in 0..2 {
                w.submit(RRequest::Attend {
                    layer,
                    tasks: vec![SeqTask {
                        seq_id: 7,
                        q: rng.normal_vec(h * d, 1.0),
                        k_new: rng.normal_vec(h * d, 1.0),
                        v_new: rng.normal_vec(h * d, 1.0),
                    }],
                })
                .unwrap();
                match w.recv().unwrap() {
                    RResponse::Outputs { outs, .. } => {
                        assert!(outs[0].1.iter().all(|x| x.is_finite()),
                            "step {step}");
                    }
                    _ => panic!(),
                }
            }
        }
        w.submit(RRequest::Stats).unwrap();
        match w.recv().unwrap() {
            RResponse::Stats(st) => assert_eq!(st.total_tokens, 20),
            _ => panic!(),
        }
    }
}
