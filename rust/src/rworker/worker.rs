//! One R-worker socket: a thread owning a SocketCache, serving
//! append+attend requests over channels (paper §4.1's R-worker loop).

use std::thread::JoinHandle;
use std::time::Duration;

use crate::kvcache::{CacheStats, SocketCache};
use crate::model::Precision;
use crate::util::chan::{bounded, Receiver, Sender};

use super::attention::{attend_one, AttnScratch};

/// Per-sequence work item within one step: the activation vectors of the
/// newest token (the only data FastDecode ships across the interconnect).
pub struct SeqTask {
    pub seq_id: u64,
    /// `[H*D]` each, head-major.
    pub q: Vec<f32>,
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
}

/// A request to one socket.
pub enum RRequest {
    /// Register sequences before first use.
    AddSeqs(Vec<u64>),
    /// Drop finished sequences.
    DropSeqs(Vec<u64>),
    /// Append K/V and compute attention for one layer of one micro-batch.
    Attend { layer: usize, tasks: Vec<SeqTask> },
    /// Report cache statistics.
    Stats,
    Shutdown,
}

/// Socket → coordinator reply.
pub enum RResponse {
    /// Outputs in task order: (seq_id, o `[H*D]`), plus busy time spent.
    /// Echoes the request's `layer` so out-of-order gathers fail loudly
    /// instead of silently crossing activations between layers.
    Outputs {
        layer: usize,
        outs: Vec<(u64, Vec<f32>)>,
        busy: std::time::Duration,
    },
    Stats(CacheStats),
    Ack,
}

/// Handle to a spawned R-worker socket thread.
pub struct RWorker {
    pub socket_id: usize,
    tx: Sender<RRequest>,
    rx: Receiver<RResponse>,
    handle: Option<JoinHandle<()>>,
}

impl RWorker {
    /// `attend_pad` artificially dilates every Attend by a sleep of
    /// `pad × tasks` — per sequence task, so the total dilation of a
    /// step is invariant to how the batch is split into mini-batches
    /// (counted in the reported busy time). Zero in production; the
    /// pipeline smoke/depth tests use it to pin the R-stage latency so
    /// the max(s, r)-vs-(s + r) assertion is robust on any machine.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        socket_id: usize,
        n_heads: usize,
        head_dim: usize,
        n_layers: usize,
        capacity_per_seq: usize,
        prec: Precision,
        attend_pad: Duration,
    ) -> RWorker {
        let (req_tx, req_rx) = bounded::<RRequest>(4);
        let (resp_tx, resp_rx) = bounded::<RResponse>(4);
        let handle = std::thread::Builder::new()
            .name(format!("rworker-{socket_id}"))
            .spawn(move || {
                run_loop(
                    req_rx,
                    resp_tx,
                    SocketCache::new(
                        n_heads,
                        head_dim,
                        n_layers,
                        capacity_per_seq,
                        prec,
                    ),
                    head_dim,
                    attend_pad,
                )
            })
            .expect("spawning rworker thread");
        RWorker {
            socket_id,
            tx: req_tx,
            rx: resp_rx,
            handle: Some(handle),
        }
    }

    /// Fire a request (does not wait for the reply).
    pub fn submit(&self, req: RRequest) {
        if self.tx.send(req).is_err() {
            panic!("rworker thread died");
        }
    }

    /// Wait for the next reply.
    pub fn recv(&self) -> RResponse {
        self.rx.recv().expect("rworker thread died")
    }
}

impl Drop for RWorker {
    fn drop(&mut self) {
        let _ = self.tx.send(RRequest::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn run_loop(
    rx: Receiver<RRequest>,
    tx: Sender<RResponse>,
    mut cache: SocketCache,
    head_dim: usize,
    attend_pad: Duration,
) {
    let mut scratch = AttnScratch::new(head_dim);
    while let Ok(req) = rx.recv() {
        match req {
            RRequest::AddSeqs(ids) => {
                for id in ids {
                    cache.add_seq(id);
                }
                let _ = tx.send(RResponse::Ack);
            }
            RRequest::DropSeqs(ids) => {
                for id in ids {
                    cache.drop_seq(id);
                }
                let _ = tx.send(RResponse::Ack);
            }
            RRequest::Attend { layer, tasks } => {
                let start = std::time::Instant::now();
                let mut outs = Vec::with_capacity(tasks.len());
                for task in &tasks {
                    let kv = cache.get_mut(task.seq_id, layer);
                    kv.append(&task.k_new, &task.v_new);
                    let mut o = vec![0.0f32; task.q.len()];
                    attend_one(kv, &task.q, &mut o, &mut scratch);
                    outs.push((task.seq_id, o));
                }
                if !attend_pad.is_zero() && !tasks.is_empty() {
                    std::thread::sleep(attend_pad * tasks.len() as u32);
                }
                let busy = start.elapsed();
                if tx.send(RResponse::Outputs { layer, outs, busy }).is_err() {
                    return;
                }
            }
            RRequest::Stats => {
                let _ = tx.send(RResponse::Stats(cache.stats()));
            }
            RRequest::Shutdown => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn worker_appends_and_attends() {
        let (h, d) = (2, 4);
        let w = RWorker::spawn(0, h, d, 1, 16, Precision::F32, Duration::ZERO);
        w.submit(RRequest::AddSeqs(vec![1, 2]));
        assert!(matches!(w.recv(), RResponse::Ack));

        let mut rng = Rng::new(3);
        let mk = |rng: &mut Rng, id| SeqTask {
            seq_id: id,
            q: rng.normal_vec(h * d, 1.0),
            k_new: rng.normal_vec(h * d, 1.0),
            v_new: rng.normal_vec(h * d, 1.0),
        };
        let t1 = mk(&mut rng, 1);
        let v1 = t1.v_new.clone();
        w.submit(RRequest::Attend {
            layer: 0,
            tasks: vec![t1, mk(&mut rng, 2)],
        });
        match w.recv() {
            RResponse::Outputs { outs, .. } => {
                assert_eq!(outs.len(), 2);
                assert_eq!(outs[0].0, 1);
                // first token ⇒ o == v_new exactly (f32 cache)
                for (a, b) in outs[0].1.iter().zip(&v1) {
                    assert!((a - b).abs() < 1e-6);
                }
            }
            _ => panic!("expected outputs"),
        }

        w.submit(RRequest::Stats);
        match w.recv() {
            RResponse::Stats(st) => {
                assert_eq!(st.sequences, 2);
                assert_eq!(st.total_tokens, 2);
            }
            _ => panic!("expected stats"),
        }

        w.submit(RRequest::DropSeqs(vec![1]));
        assert!(matches!(w.recv(), RResponse::Ack));
        w.submit(RRequest::Stats);
        match w.recv() {
            RResponse::Stats(st) => assert_eq!(st.sequences, 1),
            _ => panic!(),
        }
    }

    #[test]
    fn growing_sequence_is_consistent() {
        let (h, d) = (1, 8);
        let w = RWorker::spawn(0, h, d, 2, 32, Precision::F16, Duration::ZERO);
        w.submit(RRequest::AddSeqs(vec![7]));
        w.recv();
        let mut rng = Rng::new(4);
        for step in 0..10 {
            for layer in 0..2 {
                w.submit(RRequest::Attend {
                    layer,
                    tasks: vec![SeqTask {
                        seq_id: 7,
                        q: rng.normal_vec(h * d, 1.0),
                        k_new: rng.normal_vec(h * d, 1.0),
                        v_new: rng.normal_vec(h * d, 1.0),
                    }],
                });
                match w.recv() {
                    RResponse::Outputs { outs, .. } => {
                        assert!(outs[0].1.iter().all(|x| x.is_finite()),
                            "step {step}");
                    }
                    _ => panic!(),
                }
            }
        }
        w.submit(RRequest::Stats);
        match w.recv() {
            RResponse::Stats(st) => assert_eq!(st.total_tokens, 20),
            _ => panic!(),
        }
    }
}
