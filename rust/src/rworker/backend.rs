//! The pluggable attend backend: the surface `ThreadedPipeline` (and
//! through it `FastDecode` / `serve::ServeEngine`) needs from an R-Part
//! worker pool, extracted from `RPool` so the S↔R boundary can be an
//! in-process channel, an in-process wire loopback, or a real TCP
//! connection to `rnode` processes (`crate::net`) without the pipeline
//! knowing the difference.
//!
//! Contract shared by every implementation:
//!
//! * `add_seqs` places each new sequence on a socket (round-robin over
//!   live sockets) before its first attend; `drop_seqs` releases the
//!   KV and the placement.
//! * `submit_attend` scatters ONE layer's tasks (at most one task per
//!   sequence) and returns without waiting; `wait_attend` gathers the
//!   matching outputs. Replies are FIFO per socket, so pending handles
//!   must be waited in submission order; at most one attend may be in
//!   flight per backend in the current pipeline (see
//!   `runtime::pipeline`).
//! * Failures — a dead worker thread, a killed remote node, a malformed
//!   frame — surface as `Err` with the root cause, never as a hang or a
//!   bare panic inside the backend. After an error the backend must
//!   stay usable for sequences placed on its surviving sockets.

use anyhow::Result;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::kvcache::CacheStats;
use crate::net::codec::NodeStatsReport;
use crate::obs::{NetStats, Tracer};

use super::worker::SeqTask;

/// Handle to an attend that has been scattered to the sockets but not
/// yet gathered (returned by [`AttendBackend::submit_attend`]).
pub struct PendingAttend {
    /// Socket indices that received tasks, in scatter order.
    pub(crate) active: Vec<usize>,
    /// Echoed layer tag (out-of-order gathers fail loudly).
    pub(crate) layer: usize,
    /// Total task count (outputs are counted against it).
    pub(crate) n: usize,
    /// When the scatter completed — the start of each socket's
    /// submit→reply trace span.
    pub(crate) submitted: Instant,
}

/// Outputs of one pooled attend call.
pub struct PoolStep {
    /// seq_id → attention output `[T*H*D]` (row-major over the task's
    /// rows). BTreeMap so consumers that walk all outputs do so in
    /// ascending seq-id order — deterministic across runs and backends.
    pub outputs: BTreeMap<u64, Vec<f32>>,
    /// Max busy time across sockets (the pipeline-visible R latency).
    pub max_busy: Duration,
    /// Sum of busy times (for utilization accounting).
    pub total_busy: Duration,
    /// (socket index, busy time) for each socket that replied — the
    /// per-socket decomposition behind `StepTiming::socket_busy`/skew.
    pub socket_busy: Vec<(usize, Duration)>,
}

/// R-Part worker pool abstraction: in-process threads (`RPool`), wire
/// loopback or TCP remote nodes (`crate::net::RemotePool`).
pub trait AttendBackend: Send {
    /// Short backend label for traces and bench tables.
    fn name(&self) -> &'static str;

    /// Number of sockets (including dead ones — indices stay stable).
    fn sockets(&self) -> usize;

    /// Socket a sequence is placed on, if any.
    fn socket_of(&self, seq_id: u64) -> Option<usize>;

    /// Place and register new sequences (round-robin over live sockets).
    fn add_seqs(&mut self, seq_ids: &[u64]) -> Result<()>;

    /// Drop finished sequences and free their cache. Sequences placed
    /// on a dead socket are unplaced locally (their cache died with the
    /// socket) — dropping them is not an error.
    fn drop_seqs(&mut self, seq_ids: &[u64]) -> Result<()>;

    /// COW-fork `child` off `parent`'s first `upto` tokens on every
    /// layer. The child is placed on the PARENT's socket (shared blocks
    /// must be local to one cache) and must not already be placed.
    /// Replaces `add_seqs` for the child — it is registered by the fork.
    fn fork_seq(&mut self, parent: u64, child: u64, upto: usize)
        -> Result<()>;

    /// Scatter one layer's tasks to their sockets WITHOUT waiting for
    /// the results. At most one task per sequence per call (outputs are
    /// keyed by `seq_id`). On error, sockets that already received
    /// tasks are drained before returning so the backend stays in sync.
    fn submit_attend(
        &mut self,
        layer: usize,
        tasks: Vec<SeqTask>,
    ) -> Result<PendingAttend>;

    /// Gather one in-flight attend. On a socket failure the remaining
    /// sockets are still drained (no crossed replies for the next
    /// step), then the first root cause is returned.
    fn wait_attend(&mut self, pending: PendingAttend) -> Result<PoolStep>;

    /// Aggregate cache statistics, one entry per live socket.
    fn stats(&mut self) -> Result<Vec<CacheStats>>;

    /// Install a tracer: backends that support it create one track per
    /// socket/node and record submit→reply attend spans on it. The
    /// default ignores the tracer (tracing stays off for that backend).
    fn install_tracer(&mut self, _tracer: Tracer) {}

    /// Wire-level counters, one entry per node — frames/bytes per
    /// connection, attend ops, errors, the modeled-vs-measured payload
    /// drift detector, and the live per-node performance profile.
    /// Backends with no wire (in-process threads) report none.
    fn net_stats(&self) -> Vec<NetStats> {
        Vec::new()
    }

    /// Each live node's self-reported live snapshot
    /// (`NetRequest::NodeStats`): uptime, attend ops/rows/errors, queue
    /// wait, service percentiles, payload drift, merged cache occupancy
    /// — labeled by the node's display label. Meant for dashboards and
    /// CI (`fdtop`), not the per-step hot path. Backends with no wire
    /// report none.
    fn node_reports(&mut self) -> Result<Vec<(String, NodeStatsReport)>> {
        Ok(Vec::new())
    }

    /// Fetch every remote node's server-side trace spans
    /// (`NetRequest::FetchTrace`), remap them into the installed
    /// tracer's epoch via the node's clock-offset estimate, and merge
    /// them as one track per node. Returns the number of spans merged.
    /// All live nodes are drained before the first failure is reported,
    /// so survivors' partial traces still land even when a node died
    /// mid-fetch. Backends with no wire (or no tracer) merge nothing.
    fn merge_remote_traces(&mut self) -> Result<usize> {
        Ok(0)
    }

    /// Scatter one layer's tasks, attend in parallel, gather.
    fn attend(&mut self, layer: usize, tasks: Vec<SeqTask>) -> Result<PoolStep> {
        let pending = self.submit_attend(layer, tasks)?;
        self.wait_attend(pending)
    }
}
