//! R-workers: CPU attention near the KV-cache (paper §4.1, §5.1).
//!
//! An R-worker ("socket") owns the KV-cache of its assigned sequences
//! and, per generated token, receives Q/K/V activation vectors, appends
//! K/V, computes the attention output O, and sends it back — no model
//! parameters involved. `attention` is the pure hot path; `worker` wraps
//! it in a thread + channels; `pool` fans a batch out across sockets.

mod attention;
mod backend;
mod pool;
mod worker;

pub use attention::{
    attend_one, attend_one_f32, attend_paged, stream_bandwidth_probe,
    AttnScratch,
};
pub use backend::{AttendBackend, PendingAttend, PoolStep};
pub use pool::{RPool, RPoolConfig};
pub use worker::{RRequest, RResponse, RWorker, SeqTask};
