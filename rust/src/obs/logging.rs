//! Leveled, timestamped stderr logging behind the `FASTDECODE_LOG` env
//! var (off by default).
//!
//! Call sites use the [`obs::log!`](crate::obs_log) macro:
//!
//! ```ignore
//! obs::log!(Warn, "rnode: connection {peer}: {e:#}");
//! ```
//!
//! The level check is a single relaxed atomic load, and the format
//! arguments are only evaluated when the level is enabled — replacing
//! the previous unconditional `eprintln!` sites in `net/rnode.rs` and
//! `net/remote.rs`. Lines carry a monotonic elapsed-seconds timestamp
//! (since first log use) plus the level and module path:
//!
//! ```text
//! [   0.012345] [warn] fastdecode::net::rnode: accept failed: ...
//! ```

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, most severe first. `Off` disables everything.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

const UNSET: u8 = u8::MAX;

static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

fn parse_level(s: &str) -> u8 {
    match s.trim().to_ascii_lowercase().as_str() {
        "" | "0" | "off" | "none" => Level::Off as u8,
        "1" | "error" => Level::Error as u8,
        "2" | "warn" | "warning" => Level::Warn as u8,
        "3" | "info" => Level::Info as u8,
        "4" | "debug" | "all" => Level::Debug as u8,
        _ => Level::Off as u8,
    }
}

fn current_level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != UNSET {
        return l;
    }
    let parsed = std::env::var("FASTDECODE_LOG")
        .map(|v| parse_level(&v))
        .unwrap_or(Level::Off as u8);
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Would a message at `level` be emitted right now?
pub fn enabled(level: Level) -> bool {
    level as u8 <= current_level() && level != Level::Off
}

/// Override the level at runtime (tests; takes precedence over env).
pub fn set_level_for_test(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Emit one line to stderr. Call through [`obs::log!`](crate::obs_log),
/// which guards on [`enabled`] so arguments aren't formatted when off.
pub fn emit(level: Level, module: &str, msg: fmt::Arguments<'_>) {
    let t = epoch().elapsed().as_secs_f64();
    eprintln!("[{t:>10.6}] [{}] {module}: {msg}", level.tag());
}

/// Leveled log macro: `obs::log!(Warn, "...", args)`. The level name is
/// a bare [`Level`] variant. Expands to a single branch when the level
/// is disabled — format arguments are not evaluated.
#[macro_export]
macro_rules! obs_log {
    ($lvl:ident, $($arg:tt)*) => {
        if $crate::obs::logging::enabled($crate::obs::Level::$lvl) {
            $crate::obs::logging::emit(
                $crate::obs::Level::$lvl,
                module_path!(),
                format_args!($($arg)*),
            );
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_and_ordering() {
        assert_eq!(parse_level("warn"), Level::Warn as u8);
        assert_eq!(parse_level("DEBUG"), Level::Debug as u8);
        assert_eq!(parse_level(""), Level::Off as u8);
        assert_eq!(parse_level("garbage"), Level::Off as u8);
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn enabled_respects_runtime_level() {
        set_level_for_test(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Off));
        set_level_for_test(Level::Off);
        assert!(!enabled(Level::Error));
        // macro compiles and is inert at Off
        crate::obs_log!(Error, "should not print {}", 42);
    }
}
