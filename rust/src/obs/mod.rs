//! Observability: span tracing, leveled logging, and wire-level
//! counters — the instrument behind the paper's latency decomposition
//! (Figs 8/11/12/15: S-Part compute vs R-Part attend vs activation
//! transfer), now spanning the PROCESS BOUNDARY.
//!
//! The in-process flow is **trace → breakdown → snapshot**:
//!
//! 1. **Trace** — [`Tracer`] records wall-clock spans on per-thread
//!    tracks at every pipeline stage: S compute on the S-thread,
//!    QKV scatter and O-gather incast wait on the coordinator, one
//!    submit→reply span per socket/node on its own track, admission
//!    decisions and prefill-vs-decode rows in the serving engine. The
//!    flush is a Chrome trace-event JSON (chrome://tracing, Perfetto)
//!    built on `util::json` — one track per thread/node, so straggler
//!    skew and pipeline bubbles are visible on a timeline.
//! 2. **Breakdown** — the same timers feed
//!    `metrics::StepRecord`'s measured segments (`queue_wait_s`,
//!    `gather_wait_s`, `dispatch_s`, per-socket busy, straggler
//!    `skew_s`), which tile each step's wall latency:
//!    `accounted_s() ≈ latency_s` with a small residual
//!    (`StepRecord::residual_s`). That identity is asserted by
//!    `tests/obs_trace.rs` at every step of a live pipelined run.
//! 3. **Snapshot** — `bench::snapshot` aggregates a run's trace into a
//!    pinned machine-readable `BENCH_<name>.json` (schema documented
//!    there), starting the cross-PR perf trajectory.
//!
//! The cross-process flow is **trace → align → merge**:
//!
//! 1. **Trace (remote)** — a remote `rnode` runs its OWN [`Tracer`]
//!    against its own monotonic epoch (enabled by the `Configure`
//!    handshake's `trace` flag), recording queue-wait, frame-decode,
//!    per-layer append+attend, and output-encode spans server-side.
//!    `NetRequest::FetchTrace` drains them as [`TraceSpan`] batches.
//! 2. **Align** — monotonic clocks of different processes share no
//!    epoch, so `net::RemotePool` samples RTT pings at `Configure`
//!    time: the node answers `Ping` with its epoch-relative time, and
//!    the minimum-RTT sample's midpoint gives the clock offset with
//!    error bounded by ±RTT/2 (property-tested in
//!    `tests/net_trace.rs`).
//! 3. **Merge** — [`Tracer::merge_remote`] remaps each fetched span by
//!    that offset ([`map_remote_span`] clamps so estimate error can
//!    never yield negative timestamps/durations) and lands it on one
//!    track per node, so a single chrome://tracing view shows the
//!    S-thread, sockets, wire, AND remote node internals aligned —
//!    each node's spans nest inside the client-side submit→reply span
//!    that caused them.
//!
//! From the same measurements each node gets a live [`NodeProfile`]
//! (EWMA attend tokens/s and bytes/s, p50/p99 service time, queue
//! depth) carried in [`NetStats`] — the measured input
//! `perfmodel::Planner::from_measured_profiles` consumes in place of
//! assumed-equal device models, and what `ServeReport` and the bench
//! snapshots surface per node.
//!
//! Tracing is NEAR-ZERO-COST when disabled: [`Tracer`] is an
//! `Option<Arc<_>>`; a disabled tracer's `span`/`record`/`instant`
//! are a single branch with no clock read and no allocation, pinned
//! below 2 % of a reduced-scale fig9 step by `tests/obs_trace.rs`.
//! Enable at runtime with `FASTDECODE_TRACE=1` (picked up by every
//! engine constructor) or explicitly via the `*_traced` constructors.
//!
//! Logging ([`log!`](crate::obs_log)) is leveled and timestamped,
//! controlled by `FASTDECODE_LOG` (`error`/`warn`/`info`/`debug`, off
//! by default) — the rnode/pool noise that used to be unconditional
//! `eprintln!`s.
//!
//! Wire counters ([`TransportCounters`], [`NetStats`]) count frames
//! and bytes per connection inside the transports and attend
//! ops/errors per node in `net::RemotePool`, which also runs a live
//! drift detector: measured activation payload bytes must equal the
//! `transport::LinkModel`-modeled bytes (PR 5's pinned-bytes test
//! discipline, promoted into always-on counters).

pub mod counters;
pub mod logging;
pub mod tracer;

pub use counters::{NetStats, NodeProfile, TransportCounters};
pub use logging::Level;
pub use tracer::{
    map_remote_span, pick_clock_sync, validate_chrome_trace_file, Span,
    TraceSpan, Tracer, Track,
};

// Re-export the crate-root macro so call sites read `obs::log!`.
pub use crate::obs_log as log;
