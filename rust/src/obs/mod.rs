//! Observability: span tracing, leveled logging, and wire-level
//! counters — the instrument behind the paper's latency decomposition
//! (Figs 8/11/12/15: S-Part compute vs R-Part attend vs activation
//! transfer).
//!
//! The flow is **trace → breakdown → snapshot**:
//!
//! 1. **Trace** — [`Tracer`] records wall-clock spans on per-thread
//!    tracks at every pipeline stage: S compute on the S-thread,
//!    QKV scatter and O-gather incast wait on the coordinator, one
//!    submit→reply span per socket/node on its own track, admission
//!    decisions and prefill-vs-decode rows in the serving engine. The
//!    flush is a Chrome trace-event JSON (chrome://tracing, Perfetto)
//!    built on `util::json` — one track per thread/node, so straggler
//!    skew and pipeline bubbles are visible on a timeline.
//! 2. **Breakdown** — the same timers feed
//!    `metrics::StepRecord`'s measured segments (`queue_wait_s`,
//!    `gather_wait_s`, `dispatch_s`, per-socket busy, straggler
//!    `skew_s`), which tile each step's wall latency:
//!    `accounted_s() ≈ latency_s` with a small residual
//!    (`StepRecord::residual_s`). That identity is asserted by
//!    `tests/obs_trace.rs` at every step of a live pipelined run.
//! 3. **Snapshot** — `bench::snapshot` aggregates a run's trace into a
//!    pinned machine-readable `BENCH_<name>.json` (schema documented
//!    there), starting the cross-PR perf trajectory.
//!
//! Tracing is NEAR-ZERO-COST when disabled: [`Tracer`] is an
//! `Option<Arc<_>>`; a disabled tracer's `span`/`record`/`instant`
//! are a single branch with no clock read and no allocation, pinned
//! below 2 % of a reduced-scale fig9 step by `tests/obs_trace.rs`.
//! Enable at runtime with `FASTDECODE_TRACE=1` (picked up by every
//! engine constructor) or explicitly via the `*_traced` constructors.
//!
//! Logging ([`log!`](crate::obs_log)) is leveled and timestamped,
//! controlled by `FASTDECODE_LOG` (`error`/`warn`/`info`/`debug`, off
//! by default) — the rnode/pool noise that used to be unconditional
//! `eprintln!`s.
//!
//! Wire counters ([`TransportCounters`], [`NetStats`]) count frames
//! and bytes per connection inside the transports and attend
//! ops/errors per node in `net::RemotePool`, which also runs a live
//! drift detector: measured activation payload bytes must equal the
//! `transport::LinkModel`-modeled bytes (PR 5's pinned-bytes test
//! discipline, promoted into always-on counters).

pub mod counters;
pub mod logging;
pub mod tracer;

pub use counters::{NetStats, TransportCounters};
pub use logging::Level;
pub use tracer::{Span, Tracer, Track};

// Re-export the crate-root macro so call sites read `obs::log!`.
pub use crate::obs_log as log;
