//! Observability: the instrument behind the paper's latency
//! decomposition (Figs 8/11/12/15: S-Part compute vs R-Part attend vs
//! activation transfer), built as **two complementary surfaces** over
//! one set of measurement points:
//!
//! | surface | module | question it answers | cost model |
//! |---|---|---|---|
//! | **post-hoc traces** | [`tracer`] | *why was step N slow?* — full per-span wall-clock history, exported once at the end | spans buffered until flush |
//! | **live metrics** | [`metrics`] | *what is the system doing right now?* — current counters/gauges/percentiles, pollable mid-run | O(1) state, read any time |
//!
//! Reach for **traces** when you need causality: a Chrome trace-event
//! timeline (chrome://tracing, Perfetto) with one track per
//! thread/socket/node, where a remote node's decode/append/attend
//! spans nest inside the client-side submit→reply span that caused
//! them. Reach for **metrics** when you need a dashboard: the `fdtop`
//! binary polls a running cluster's live snapshots without stopping
//! it, and `FASTDECODE_METRICS=1` turns on the in-process registry for
//! Prometheus-style text or JSON export. Traces answer questions about
//! a run that already happened; metrics answer questions about a run
//! that is still going.
//!
//! # Surface 1: post-hoc traces (PR 6/9)
//!
//! The in-process flow is **trace → breakdown → snapshot**:
//!
//! 1. **Trace** — [`Tracer`] records wall-clock spans on per-thread
//!    tracks at every pipeline stage: S compute on the S-thread,
//!    QKV scatter and O-gather incast wait on the coordinator, one
//!    submit→reply span per socket/node on its own track, admission
//!    decisions and prefill-vs-decode rows in the serving engine. The
//!    flush is a Chrome trace-event JSON built on `util::json`.
//! 2. **Breakdown** — the same timers feed
//!    `metrics::StepRecord`'s measured segments (`queue_wait_s`,
//!    `gather_wait_s`, `dispatch_s`, per-socket busy, straggler
//!    `skew_s`), which tile each step's wall latency:
//!    `accounted_s() ≈ latency_s` with a small residual
//!    (`StepRecord::residual_s`). That identity is asserted by
//!    `tests/obs_trace.rs` at every step of a live pipelined run.
//! 3. **Snapshot** — `bench::snapshot` aggregates a run's trace into a
//!    pinned machine-readable `BENCH_<name>.json` (schema documented
//!    there) — the cross-PR perf trajectory that
//!    `bench_validate --compare` gates against `bench.baseline.json`.
//!
//! The cross-process flow is **trace → align → merge**:
//!
//! 1. **Trace (remote)** — a remote `rnode` runs its OWN [`Tracer`]
//!    against its own monotonic epoch (enabled by the `Configure`
//!    handshake's `trace` flag), recording queue-wait, frame-decode,
//!    per-layer append+attend, and output-encode spans server-side.
//!    `NetRequest::FetchTrace` drains them as [`TraceSpan`] batches.
//! 2. **Align** — monotonic clocks of different processes share no
//!    epoch, so `net::RemotePool` samples RTT pings at `Configure`
//!    time: the node answers `Ping` with its epoch-relative time, and
//!    the minimum-RTT sample's midpoint gives the clock offset with
//!    error bounded by ±RTT/2 (property-tested in
//!    `tests/net_trace.rs`).
//! 3. **Merge** — [`Tracer::merge_remote`] remaps each fetched span by
//!    that offset ([`map_remote_span`] clamps so estimate error can
//!    never yield negative timestamps/durations) and lands it on one
//!    track per node — one aligned timeline across processes.
//!
//! # Surface 2: live metrics (this PR)
//!
//! [`metrics::Metrics`] is a process-wide registry of labeled
//! counters, gauges, histograms (reusing `crate::metrics::Histogram` —
//! one percentile implementation repo-wide) and fixed-capacity
//! time-series ring buffers, enabled by `FASTDECODE_METRICS=1` and
//! exported as Prometheus-style text or JSON. Built-in
//! instrumentation: the serve engine (active slots, queue depth,
//! admissions/completions, live TTFT/ITL/goodput), the pipeline (step
//! latency histogram + stage-breakdown gauges), `net::RemotePool`
//! (per-node in-flight, errors, EWMA rates from [`NodeProfile`]), and
//! `kvcache` (blocks used/free, physical-vs-logical utilization).
//!
//! The live surface also crosses the process boundary: every `rnode`
//! listener keeps shared self-counters (`net::rnode::NodeShared`) and
//! answers `NetRequest::NodeStats` with a `NodeStatsReport` snapshot —
//! uptime, attend ops/rows/errors, queue wait, service percentiles,
//! payload drift, and merged cache occupancy — on ANY connection,
//! including an unconfigured monitor connection. The `fdtop` binary
//! (`net::monitor`) polls those reports into a live per-node table or
//! a `--once --json` document for scripting and CI; a dead node
//! renders as a DEAD row instead of aborting the poll.
//!
//! Both surfaces are NEAR-ZERO-COST when disabled: [`Tracer`] and
//! [`metrics::Metrics`] are `Option<Arc<_>>` handles; disabled ops are
//! a single branch with no clock read and no allocation (pinned below
//! 2 % of a reduced-scale fig9 step by `tests/obs_trace.rs`).
//!
//! Logging ([`log!`](crate::obs_log)) is leveled and timestamped,
//! controlled by `FASTDECODE_LOG` (`error`/`warn`/`info`/`debug`, off
//! by default) — the rnode/pool noise that used to be unconditional
//! `eprintln!`s.
//!
//! Wire counters ([`TransportCounters`], [`NetStats`]) count frames
//! and bytes per connection inside the transports and attend
//! ops/errors per node in `net::RemotePool`, which also runs a live
//! drift detector: measured activation payload bytes must equal the
//! `transport::LinkModel`-modeled bytes (PR 5's pinned-bytes test
//! discipline, promoted into always-on counters). From the same
//! submit→reply timing each node gets a live [`NodeProfile`] (EWMA
//! attend tokens/s and bytes/s, p50/p99 service time, queue depth)
//! carried in [`NetStats`] — the measured input
//! `perfmodel::Planner::from_measured_profiles` consumes in place of
//! assumed-equal device models.

pub mod counters;
pub mod logging;
pub mod metrics;
pub mod tracer;

pub use counters::{NetStats, NodeProfile, TransportCounters};
pub use logging::Level;
pub use metrics::{Metrics, RingSeries};
pub use tracer::{
    map_remote_span, pick_clock_sync, validate_chrome_trace_file, Span,
    TraceSpan, Tracer, Track,
};

// Re-export the crate-root macro so call sites read `obs::log!`.
pub use crate::obs_log as log;
