//! Span-based tracer with a Chrome trace-event JSON exporter.
//!
//! Design constraints, in order:
//!
//! * **Near-zero cost when disabled.** [`Tracer`] wraps an
//!   `Option<Arc<_>>`; every op on a disabled tracer/track is one
//!   branch — no clock read, no allocation, no lock. The pipeline
//!   threads a tracer through its hot path unconditionally, so this is
//!   what keeps tracing out of the throughput numbers
//!   (pinned < 2 % by `tests/obs_trace.rs`).
//! * **Lock-free-ish buffers.** Each [`Track`] owns its own
//!   `Mutex<Vec<Event>>`; a track is used by exactly one thread
//!   (S-thread, coordinator, one per socket/node), so the lock is
//!   uncontended on the hot path and only the flush walks all tracks.
//! * **Monotonic clock.** All timestamps are `Instant`s against one
//!   epoch captured at tracer creation, exported as microseconds —
//!   the unit Chrome's `ts`/`dur` fields expect.
//!
//! The export ([`Tracer::chrome_trace`]) is the Chrome trace-event
//! format (loads in chrome://tracing and Perfetto): one `"M"`
//! `thread_name` metadata event per track and one `"X"` complete event
//! per span (`"i"` for instants), all in `pid` 0 with the track index
//! as `tid` — one horizontal track per thread/node. Attribution
//! (layer, mini-batch, socket, rows) travels in numeric `args`.
//!
//! **Cross-process traces.** A remote `rnode` records spans against its
//! OWN epoch and ships them back as [`TraceSpan`] batches
//! (`NetResponse::Trace`). The client estimates the node's clock offset
//! from RTT ping samples (min-RTT midpoint; `net::RemotePool`), then
//! [`Tracer::merge_remote`] remaps each remote span into this tracer's
//! epoch via [`map_remote_span`] and lands it on its own track — one
//! chrome://tracing view of S-thread, sockets, wire, and remote node
//! internals on a single aligned timeline.

use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, ensure, Context as _, Result};

use crate::util::json::Json;

/// One recorded event (a complete span or an instant).
#[derive(Clone, Debug)]
pub struct Event {
    pub name: String,
    /// Chrome phase: `"X"` complete span, `"i"` instant.
    pub ph: &'static str,
    /// Microseconds since the tracer's epoch.
    pub ts_us: f64,
    /// Span duration in microseconds (0 for instants).
    pub dur_us: f64,
    /// Numeric attribution (layer, mb, socket, rows, …).
    pub args: Vec<(String, f64)>,
}

/// One span in transit between processes: a [`Event`] plus the name of
/// the track it was recorded on, timestamped against the REMOTE
/// process's epoch. This is the payload of `NetResponse::Trace`; the
/// receiving side remaps `ts_us` with [`map_remote_span`] before it
/// joins the local timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSpan {
    /// Remote track name (e.g. `"rnode"`).
    pub track: String,
    pub name: String,
    /// `true` for instants (`"i"`), `false` for complete spans (`"X"`).
    pub instant: bool,
    /// Microseconds since the REMOTE epoch.
    pub ts_us: f64,
    pub dur_us: f64,
    pub args: Vec<(String, f64)>,
}

/// Pick the clock-sync sample out of an RTT ping burst. Each sample is
/// `(send_us, node_us, recv_us)`: the client-side send/receive times of
/// one `Ping` round trip (any client epoch) and the node's
/// epoch-relative reply. The minimum-RTT sample wins; at its client-side
/// midpoint the node's clock read `node_us`, so
/// `offset_us = mid_us − node_us` maps remote time into client time
/// with error bounded by ±`min_rtt/2` no matter how asymmetrically the
/// two legs split the round trip (the error is exactly
/// `(back − out)/2`). Returns `(mid_us, node_us, min_rtt_us)`; `None`
/// when no sample is usable (empty burst, non-finite or negative RTT).
/// Pure — property-tested under randomized asymmetric delays in
/// `tests/net_trace.rs`; `net::RemotePool` builds its per-node
/// `ClockSync` from this.
pub fn pick_clock_sync(
    samples: &[(f64, f64, f64)],
) -> Option<(f64, f64, f64)> {
    let mut best: Option<(f64, f64, f64)> = None;
    for &(send, node, recv) in samples {
        let rtt = recv - send;
        if !rtt.is_finite() || rtt < 0.0 {
            continue;
        }
        if best.map_or(true, |(_, _, min)| rtt < min) {
            best = Some(((send + recv) / 2.0, node, rtt));
        }
    }
    best
}

/// Remap one remote span into the local epoch: shift by the estimated
/// clock offset (local_us ≈ remote_us + offset_us), then clamp into
/// `window = (lo_us, hi_us)` so an offset-estimate error can never
/// produce a negative timestamp, a negative duration, or a span poking
/// outside the window it must nest in. Pure — property-tested under
/// randomized asymmetric RTT jitter in `tests/net_trace.rs`.
pub fn map_remote_span(
    ts_us: f64,
    dur_us: f64,
    offset_us: f64,
    window: (f64, f64),
) -> (f64, f64) {
    let (lo, hi) = window;
    let hi = hi.max(lo);
    let start = (ts_us + offset_us).clamp(lo, hi);
    let end = (ts_us + offset_us + dur_us.max(0.0)).clamp(start, hi);
    (start, end - start)
}

struct TrackBuf {
    name: String,
    events: Arc<Mutex<Vec<Event>>>,
}

struct Inner {
    epoch: Instant,
    tracks: Mutex<Vec<TrackBuf>>,
}

/// Cheap-to-clone handle to one trace session (or to nothing, when
/// disabled). Every engine constructor takes one; `Tracer::from_env()`
/// is the default, so `FASTDECODE_TRACE=1` turns any run into a trace.
#[derive(Clone, Default)]
pub struct Tracer {
    inner: Option<Arc<Inner>>,
}

impl Tracer {
    /// A no-op tracer: every op is a single branch.
    pub fn disabled() -> Tracer {
        Tracer { inner: None }
    }

    /// An active tracer; the epoch (ts = 0) is now.
    pub fn enabled() -> Tracer {
        Tracer::enabled_with_epoch(Instant::now())
    }

    /// An active tracer with an explicit epoch — `rnode` pins its
    /// tracer to the connection-accept instant so the same epoch
    /// anchors both its spans and the `Ping` clock-sync replies.
    pub fn enabled_with_epoch(epoch: Instant) -> Tracer {
        Tracer {
            inner: Some(Arc::new(Inner {
                epoch,
                tracks: Mutex::new(Vec::new()),
            })),
        }
    }

    /// Enabled iff `FASTDECODE_TRACE` is set to something other than
    /// `0`/`""` (checked once per process).
    pub fn from_env() -> Tracer {
        static ON: OnceLock<bool> = OnceLock::new();
        let on = *ON.get_or_init(|| {
            std::env::var("FASTDECODE_TRACE")
                .map(|v| !v.is_empty() && v != "0")
                .unwrap_or(false)
        });
        if on {
            Tracer::enabled()
        } else {
            Tracer::disabled()
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Microseconds from this tracer's epoch to `t` (clamped at 0; 0 on
    /// a disabled tracer). The clock-offset estimator uses this to
    /// express its ping midpoints in trace time.
    pub fn us_since_epoch(&self, t: Instant) -> f64 {
        match &self.inner {
            Some(inner) => {
                t.saturating_duration_since(inner.epoch).as_secs_f64() * 1e6
            }
            None => 0.0,
        }
    }

    /// Take every recorded event out of every track, tagged with its
    /// track name — the serialization point for `NetResponse::Trace`.
    /// Buffers are left empty (a second fetch returns only new spans);
    /// track registrations stay. Empty on a disabled tracer.
    pub fn drain_remote_spans(&self) -> Vec<TraceSpan> {
        let Some(inner) = &self.inner else {
            return Vec::new();
        };
        let mut out = Vec::new();
        let tracks = inner.tracks.lock().expect("track registry");
        for t in tracks.iter() {
            let events =
                std::mem::take(&mut *t.events.lock().expect("track buffer"));
            for e in events {
                out.push(TraceSpan {
                    track: t.name.clone(),
                    name: e.name,
                    instant: e.ph != "X",
                    ts_us: e.ts_us,
                    dur_us: e.dur_us,
                    args: e.args,
                });
            }
        }
        out
    }

    /// Fold a fetched batch of remote spans into this trace as ONE new
    /// track named `label`, remapping each span's remote-epoch
    /// timestamp by `offset_us` (local ≈ remote + offset) and clamping
    /// into `[0, now]` via [`map_remote_span`]. Returns the number of
    /// spans merged (0 on a disabled tracer).
    pub fn merge_remote(
        &self,
        label: &str,
        spans: Vec<TraceSpan>,
        offset_us: f64,
    ) -> usize {
        if self.inner.is_none() || spans.is_empty() {
            return 0;
        }
        let window = (0.0, self.us_since_epoch(Instant::now()));
        let track = self.track(label);
        let Some(h) = &track.inner else {
            return 0;
        };
        let mut merged = 0usize;
        for s in spans {
            let (ts_us, dur_us) =
                map_remote_span(s.ts_us, s.dur_us, offset_us, window);
            h.push_raw(Event {
                name: s.name,
                ph: if s.instant { "i" } else { "X" },
                ts_us,
                dur_us,
                args: s.args,
            });
            merged += 1;
        }
        merged
    }

    /// Register a new track (one per thread/node; `name` becomes the
    /// Chrome thread name). On a disabled tracer this is free and the
    /// returned track is a no-op.
    pub fn track(&self, name: &str) -> Track {
        let Some(inner) = &self.inner else {
            return Track { inner: None };
        };
        let events = Arc::new(Mutex::new(Vec::new()));
        inner.tracks.lock().expect("track registry").push(TrackBuf {
            name: name.to_string(),
            events: events.clone(),
        });
        Track {
            inner: Some(TrackHandle {
                epoch: inner.epoch,
                events,
            }),
        }
    }

    /// Merge every track's buffer into one Chrome trace-event JSON
    /// document (`{"traceEvents": [...]}`).
    pub fn chrome_trace(&self) -> Json {
        let mut events: Vec<Json> = Vec::new();
        events.push(
            Json::obj()
                .set("ph", "M")
                .set("name", "process_name")
                .set("pid", 0usize)
                .set("tid", 0usize)
                .set("args", Json::obj().set("name", "fastdecode")),
        );
        if let Some(inner) = &self.inner {
            let tracks = inner.tracks.lock().expect("track registry");
            for (tid, t) in tracks.iter().enumerate() {
                events.push(
                    Json::obj()
                        .set("ph", "M")
                        .set("name", "thread_name")
                        .set("pid", 0usize)
                        .set("tid", tid)
                        .set("args", Json::obj().set("name", t.name.as_str())),
                );
                for e in t.events.lock().expect("track buffer").iter() {
                    let mut args = Json::obj();
                    for (k, v) in &e.args {
                        args = args.set(k.as_str(), *v);
                    }
                    let mut j = Json::obj()
                        .set("ph", e.ph)
                        .set("name", e.name.as_str())
                        .set("cat", "fastdecode")
                        .set("pid", 0usize)
                        .set("tid", tid)
                        .set("ts", e.ts_us);
                    if e.ph == "X" {
                        j = j.set("dur", e.dur_us);
                    } else {
                        // instant scope: thread
                        j = j.set("s", "t");
                    }
                    events.push(j.set("args", args));
                }
            }
        }
        Json::obj()
            .set("traceEvents", Json::Arr(events))
            .set("displayTimeUnit", "ms")
    }

    /// Write the Chrome trace to `path` (creating parent dirs).
    pub fn write_chrome_trace(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        std::fs::write(path, self.chrome_trace().render())
            .with_context(|| format!("writing trace to {}", path.display()))
    }
}

/// Validate a `TRACE_*.json` artifact on disk — the CI gate run by
/// `bench_validate --chrome-trace`. Checks, in order:
///
/// * the file parses as JSON (`util::json`, the same parser the rest of
///   the project trusts) and holds a non-empty `traceEvents` array;
/// * at least `min_tracks` `thread_name` metadata events are present
///   (one per expected track: with N remote nodes merged, N node tracks
///   on top of the local ones);
/// * every event carries a known phase (`M`/`X`/`i`) and finite,
///   non-negative `ts` (and `dur` for `X` spans);
/// * per track, span COMPLETION times (`ts + dur`) are monotone
///   non-decreasing in document order — the order events are recorded
///   in on a single thread, preserved by drain → merge. A violation
///   means the clock-offset remap reordered or corrupted a batch.
pub fn validate_chrome_trace_file(
    path: &std::path::Path,
    min_tracks: usize,
) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let doc = Json::parse(&text)
        .with_context(|| format!("parsing {}", path.display()))?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .context("missing traceEvents array")?;
    ensure!(!events.is_empty(), "traceEvents is empty");

    let mut tracks = 0usize;
    // (tid, last span end) per track; tids are small dense ints, a vec
    // scan beats pulling in a map.
    let mut last_end: Vec<(f64, f64)> = Vec::new();
    const EPS_US: f64 = 1.0; // float-rounding slack on the µs clock
    for (i, e) in events.iter().enumerate() {
        let ph = e
            .get("ph")
            .and_then(Json::as_str)
            .with_context(|| format!("event {i}: missing ph"))?;
        match ph {
            "M" => {
                if e.get("name").and_then(Json::as_str)
                    == Some("thread_name")
                {
                    tracks += 1;
                }
            }
            "X" | "i" => {
                let ts = e
                    .get("ts")
                    .and_then(Json::as_f64)
                    .with_context(|| format!("event {i}: missing ts"))?;
                ensure!(
                    ts.is_finite() && ts >= 0.0,
                    "event {i}: bad ts {ts}"
                );
                let dur = if ph == "X" {
                    let d = e
                        .get("dur")
                        .and_then(Json::as_f64)
                        .with_context(|| format!("event {i}: missing dur"))?;
                    ensure!(
                        d.is_finite() && d >= 0.0,
                        "event {i}: bad dur {d}"
                    );
                    d
                } else {
                    0.0
                };
                let tid = e
                    .get("tid")
                    .and_then(Json::as_f64)
                    .with_context(|| format!("event {i}: missing tid"))?;
                let end = ts + dur;
                match last_end.iter_mut().find(|(t, _)| *t == tid) {
                    Some((_, prev)) => {
                        ensure!(
                            end >= *prev - EPS_US,
                            "event {i}: track {tid} span ends at {end} \
                             before the previous span's {prev}"
                        );
                        *prev = prev.max(end);
                    }
                    None => last_end.push((tid, end)),
                }
            }
            other => bail!("event {i}: unknown phase {other:?}"),
        }
    }
    ensure!(
        tracks >= min_tracks,
        "only {tracks} thread_name tracks, expected at least {min_tracks}"
    );
    Ok(())
}

#[derive(Clone)]
struct TrackHandle {
    epoch: Instant,
    events: Arc<Mutex<Vec<Event>>>,
}

impl TrackHandle {
    fn push(
        &self,
        name: &str,
        ph: &'static str,
        start: Instant,
        end: Instant,
        args: &[(&'static str, f64)],
    ) {
        // spans from before the epoch (a caller's stale Instant) clamp
        // to 0 instead of going negative
        let ts_us = end
            .min(start)
            .max(self.epoch)
            .duration_since(self.epoch)
            .as_secs_f64()
            * 1e6;
        let dur_us = end.saturating_duration_since(start).as_secs_f64() * 1e6;
        self.events.lock().expect("track buffer").push(Event {
            name: name.to_string(),
            ph,
            ts_us,
            dur_us,
            args: args.iter().map(|&(k, v)| (k.to_string(), v)).collect(),
        });
    }

    /// Append a pre-timestamped event (already in THIS tracer's epoch)
    /// — the merge path for remote spans, which carry explicit `ts_us`
    /// rather than `Instant`s.
    fn push_raw(&self, event: Event) {
        self.events.lock().expect("track buffer").push(event);
    }
}

/// One thread's (or node's) event buffer. Cheap to clone; all ops are
/// no-ops when the parent tracer is disabled.
#[derive(Clone, Default)]
pub struct Track {
    inner: Option<TrackHandle>,
}

impl Track {
    /// A no-op track, for fields that may never see an installed
    /// tracer.
    pub fn disabled() -> Track {
        Track { inner: None }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Guard-based span: starts now, records when dropped. Scopes drop
    /// guards LIFO, so spans on one track nest properly by
    /// construction.
    pub fn span(&self, name: &'static str) -> Span {
        let Some(h) = &self.inner else {
            return Span { inner: None };
        };
        Span {
            inner: Some(SpanInner {
                handle: h.clone(),
                name,
                start: Instant::now(),
                args: Vec::new(),
            }),
        }
    }

    /// Explicit span between two timestamps the caller measured — the
    /// client-side per-socket submit→reply spans use this.
    pub fn record(
        &self,
        name: &'static str,
        start: Instant,
        end: Instant,
        args: &[(&'static str, f64)],
    ) {
        if let Some(h) = &self.inner {
            h.push(name, "X", start, end, args);
        }
    }

    /// Zero-duration instant event (admission decisions etc.).
    pub fn instant(&self, name: &'static str, args: &[(&'static str, f64)]) {
        if let Some(h) = &self.inner {
            let now = Instant::now();
            h.push(name, "i", now, now, args);
        }
    }
}

struct SpanInner {
    handle: TrackHandle,
    name: &'static str,
    start: Instant,
    args: Vec<(&'static str, f64)>,
}

/// Span guard returned by [`Track::span`]; records on drop.
pub struct Span {
    inner: Option<SpanInner>,
}

impl Span {
    /// Attach a numeric attribute (builder style; free when disabled).
    pub fn arg(mut self, key: &'static str, value: f64) -> Span {
        if let Some(i) = &mut self.inner {
            i.args.push((key, value));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(i) = self.inner.take() {
            i.handle.push(i.name, "X", i.start, Instant::now(), &i.args);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn disabled_tracer_is_inert() {
        let tr = Tracer::disabled();
        assert!(!tr.is_enabled());
        let t = tr.track("t");
        assert!(!t.is_enabled());
        let _s = t.span("x").arg("k", 1.0);
        t.instant("i", &[]);
        t.record("r", Instant::now(), Instant::now(), &[]);
        let j = tr.chrome_trace().render();
        // only the process_name metadata event
        assert!(j.contains("traceEvents"));
        assert!(!j.contains("thread_name"));
    }

    #[test]
    fn spans_and_instants_export() {
        let tr = Tracer::enabled();
        let t = tr.track("worker");
        {
            let _a = t.span("outer").arg("layer", 3.0);
            let _b = t.span("inner");
        }
        t.instant("mark", &[("x", 1.0)]);
        let s = tr.chrome_trace().render();
        assert!(s.contains("\"thread_name\""));
        assert!(s.contains("\"worker\""));
        assert!(s.contains("\"outer\""));
        assert!(s.contains("\"inner\""));
        assert!(s.contains("\"mark\""));
        assert!(s.contains("\"layer\":3"));
        // the export must itself be valid JSON
        let parsed = Json::parse(&s).expect("chrome trace parses");
        let events = parsed
            .get("traceEvents")
            .and_then(Json::as_arr)
            .expect("traceEvents array");
        // process_name + thread_name + outer + inner + mark
        assert_eq!(events.len(), 5);
    }

    /// Random nesting: guards drop LIFO per scope, so the flushed
    /// events must form a valid nesting (every pair of spans on one
    /// track is either disjoint or contained) and the export must be a
    /// parseable Chrome trace.
    #[test]
    fn prop_span_nesting_is_valid_chrome_trace() {
        prop::check("tracer-nesting", 30, |g| {
            let tr = Tracer::enabled();
            let track = tr.track("t");
            let mut expected = 0usize;
            // random recursive span tree, depth ≤ 4
            fn descend(
                t: &Track,
                g: &mut prop::Gen,
                depth: usize,
                count: &mut usize,
            ) {
                let kids = g.usize_in(0, 3);
                for _ in 0..kids {
                    let _s = t.span("n");
                    *count += 1;
                    if depth < 4 {
                        descend(t, g, depth + 1, count);
                    }
                }
            }
            descend(&track, g, 0, &mut expected);
            let parsed =
                Json::parse(&tr.chrome_trace().render()).expect("parses");
            let events = parsed
                .get("traceEvents")
                .and_then(Json::as_arr)
                .expect("traceEvents");
            let mut spans: Vec<(f64, f64)> = events
                .iter()
                .filter(|e| {
                    e.get("ph").and_then(Json::as_str) == Some("X")
                })
                .map(|e| {
                    let ts = e.get("ts").and_then(Json::as_f64).unwrap();
                    let dur = e.get("dur").and_then(Json::as_f64).unwrap();
                    (ts, ts + dur)
                })
                .collect();
            assert_eq!(spans.len(), expected);
            // sort by start asc, end desc: parents precede children
            spans.sort_by(|a, b| {
                a.0.total_cmp(&b.0).then(b.1.total_cmp(&a.1))
            });
            let eps = 0.5; // µs: clock granularity slack
            let mut stack: Vec<(f64, f64)> = Vec::new();
            for (s, e) in spans {
                while let Some(&(_, te)) = stack.last() {
                    if s >= te - eps {
                        stack.pop();
                    } else {
                        break;
                    }
                }
                if let Some(&(ts, te)) = stack.last() {
                    assert!(
                        s >= ts - eps && e <= te + eps,
                        "span ({s}, {e}) straddles ({ts}, {te})"
                    );
                }
                stack.push((s, e));
            }
        });
    }

    /// Drain → merge round trip: a "remote" tracer's spans land on a
    /// fresh local track with the offset applied, clamped into the
    /// local timeline, and the remote buffers come back empty.
    #[test]
    fn drain_and_merge_remote_spans() {
        let remote = Tracer::enabled();
        let rt = remote.track("rnode");
        {
            let _s = rt.span("attend").arg("layer", 1.0);
        }
        rt.instant("mark", &[]);
        let spans = remote.drain_remote_spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].track, "rnode");
        assert!(!spans[0].instant);
        assert!(spans[1].instant);
        assert!(
            remote.drain_remote_spans().is_empty(),
            "drain must empty the buffers"
        );

        let local = Tracer::enabled();
        let merged = local.merge_remote("node0", spans, 0.0);
        assert_eq!(merged, 2);
        let parsed = Json::parse(&local.chrome_trace().render()).unwrap();
        let events = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
        let has_track = events.iter().any(|e| {
            e.get("name").and_then(Json::as_str) == Some("thread_name")
                && e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str)
                    == Some("node0")
        });
        assert!(has_track, "merged spans must land on their own track");
        for e in events.iter().filter(|e| {
            e.get("ph").and_then(Json::as_str) == Some("X")
        }) {
            let ts = e.get("ts").and_then(Json::as_f64).unwrap();
            let dur = e.get("dur").and_then(Json::as_f64).unwrap();
            assert!(ts >= 0.0 && dur >= 0.0);
        }
    }

    /// The pure remap clamps hostile inputs: negative durations, spans
    /// before the window, spans past it.
    #[test]
    fn map_remote_span_clamps() {
        let w = (10.0, 100.0);
        assert_eq!(map_remote_span(0.0, 5.0, 0.0, w), (10.0, 0.0));
        assert_eq!(map_remote_span(50.0, -3.0, 0.0, w), (50.0, 0.0));
        let (ts, dur) = map_remote_span(90.0, 50.0, 0.0, w);
        assert_eq!((ts, ts + dur), (90.0, 100.0));
        // offset shifts before clamping
        assert_eq!(map_remote_span(30.0, 10.0, 20.0, w), (50.0, 10.0));
    }

    /// The CI validator accepts a real export (local + merged remote
    /// tracks) and rejects shortfalls and corruption.
    #[test]
    fn chrome_trace_file_validator() {
        let tr = Tracer::enabled();
        let t = tr.track("local");
        {
            let _s = t.span("work");
        }
        let remote = Tracer::enabled();
        {
            let _s = remote.track("rnode").span("attend");
        }
        assert_eq!(
            tr.merge_remote("rnode0", remote.drain_remote_spans(), 0.0),
            1
        );
        let path = std::env::temp_dir()
            .join(format!("fd_trace_validate_{}.json", std::process::id()));
        tr.write_chrome_trace(&path).unwrap();
        validate_chrome_trace_file(&path, 2).expect("valid trace");
        let err = validate_chrome_trace_file(&path, 9).unwrap_err();
        assert!(err.to_string().contains("thread_name tracks"), "{err:#}");
        // corruption: a negative duration must fail
        std::fs::write(
            &path,
            r#"{"traceEvents":[{"ph":"X","ts":1,"dur":-2,"tid":0}]}"#,
        )
        .unwrap();
        assert!(validate_chrome_trace_file(&path, 0).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn record_clamps_stale_starts() {
        let before = Instant::now();
        let tr = Tracer::enabled();
        let t = tr.track("t");
        t.record("old", before, Instant::now(), &[]);
        let parsed = Json::parse(&tr.chrome_trace().render()).unwrap();
        let ts = parsed.get("traceEvents").and_then(Json::as_arr).unwrap()
            [2]
        .get("ts")
        .and_then(Json::as_f64)
        .unwrap();
        assert!(ts >= 0.0);
    }
}
