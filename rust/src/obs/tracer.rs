//! Span-based tracer with a Chrome trace-event JSON exporter.
//!
//! Design constraints, in order:
//!
//! * **Near-zero cost when disabled.** [`Tracer`] wraps an
//!   `Option<Arc<_>>`; every op on a disabled tracer/track is one
//!   branch — no clock read, no allocation, no lock. The pipeline
//!   threads a tracer through its hot path unconditionally, so this is
//!   what keeps tracing out of the throughput numbers
//!   (pinned < 2 % by `tests/obs_trace.rs`).
//! * **Lock-free-ish buffers.** Each [`Track`] owns its own
//!   `Mutex<Vec<Event>>`; a track is used by exactly one thread
//!   (S-thread, coordinator, one per socket/node), so the lock is
//!   uncontended on the hot path and only the flush walks all tracks.
//! * **Monotonic clock.** All timestamps are `Instant`s against one
//!   epoch captured at tracer creation, exported as microseconds —
//!   the unit Chrome's `ts`/`dur` fields expect.
//!
//! The export ([`Tracer::chrome_trace`]) is the Chrome trace-event
//! format (loads in chrome://tracing and Perfetto): one `"M"`
//! `thread_name` metadata event per track and one `"X"` complete event
//! per span (`"i"` for instants), all in `pid` 0 with the track index
//! as `tid` — one horizontal track per thread/node. Attribution
//! (layer, mini-batch, socket, rows) travels in numeric `args`.

use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context as _, Result};

use crate::util::json::Json;

/// One recorded event (a complete span or an instant).
#[derive(Clone, Debug)]
pub struct Event {
    pub name: String,
    /// Chrome phase: `"X"` complete span, `"i"` instant.
    pub ph: &'static str,
    /// Microseconds since the tracer's epoch.
    pub ts_us: f64,
    /// Span duration in microseconds (0 for instants).
    pub dur_us: f64,
    /// Numeric attribution (layer, mb, socket, rows, …).
    pub args: Vec<(&'static str, f64)>,
}

struct TrackBuf {
    name: String,
    events: Arc<Mutex<Vec<Event>>>,
}

struct Inner {
    epoch: Instant,
    tracks: Mutex<Vec<TrackBuf>>,
}

/// Cheap-to-clone handle to one trace session (or to nothing, when
/// disabled). Every engine constructor takes one; `Tracer::from_env()`
/// is the default, so `FASTDECODE_TRACE=1` turns any run into a trace.
#[derive(Clone, Default)]
pub struct Tracer {
    inner: Option<Arc<Inner>>,
}

impl Tracer {
    /// A no-op tracer: every op is a single branch.
    pub fn disabled() -> Tracer {
        Tracer { inner: None }
    }

    /// An active tracer; the epoch (ts = 0) is now.
    pub fn enabled() -> Tracer {
        Tracer {
            inner: Some(Arc::new(Inner {
                epoch: Instant::now(),
                tracks: Mutex::new(Vec::new()),
            })),
        }
    }

    /// Enabled iff `FASTDECODE_TRACE` is set to something other than
    /// `0`/`""` (checked once per process).
    pub fn from_env() -> Tracer {
        static ON: OnceLock<bool> = OnceLock::new();
        let on = *ON.get_or_init(|| {
            std::env::var("FASTDECODE_TRACE")
                .map(|v| !v.is_empty() && v != "0")
                .unwrap_or(false)
        });
        if on {
            Tracer::enabled()
        } else {
            Tracer::disabled()
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Register a new track (one per thread/node; `name` becomes the
    /// Chrome thread name). On a disabled tracer this is free and the
    /// returned track is a no-op.
    pub fn track(&self, name: &str) -> Track {
        let Some(inner) = &self.inner else {
            return Track { inner: None };
        };
        let events = Arc::new(Mutex::new(Vec::new()));
        inner.tracks.lock().expect("track registry").push(TrackBuf {
            name: name.to_string(),
            events: events.clone(),
        });
        Track {
            inner: Some(TrackHandle {
                epoch: inner.epoch,
                events,
            }),
        }
    }

    /// Merge every track's buffer into one Chrome trace-event JSON
    /// document (`{"traceEvents": [...]}`).
    pub fn chrome_trace(&self) -> Json {
        let mut events: Vec<Json> = Vec::new();
        events.push(
            Json::obj()
                .set("ph", "M")
                .set("name", "process_name")
                .set("pid", 0usize)
                .set("tid", 0usize)
                .set("args", Json::obj().set("name", "fastdecode")),
        );
        if let Some(inner) = &self.inner {
            let tracks = inner.tracks.lock().expect("track registry");
            for (tid, t) in tracks.iter().enumerate() {
                events.push(
                    Json::obj()
                        .set("ph", "M")
                        .set("name", "thread_name")
                        .set("pid", 0usize)
                        .set("tid", tid)
                        .set("args", Json::obj().set("name", t.name.as_str())),
                );
                for e in t.events.lock().expect("track buffer").iter() {
                    let mut args = Json::obj();
                    for &(k, v) in &e.args {
                        args = args.set(k, v);
                    }
                    let mut j = Json::obj()
                        .set("ph", e.ph)
                        .set("name", e.name.as_str())
                        .set("cat", "fastdecode")
                        .set("pid", 0usize)
                        .set("tid", tid)
                        .set("ts", e.ts_us);
                    if e.ph == "X" {
                        j = j.set("dur", e.dur_us);
                    } else {
                        // instant scope: thread
                        j = j.set("s", "t");
                    }
                    events.push(j.set("args", args));
                }
            }
        }
        Json::obj()
            .set("traceEvents", Json::Arr(events))
            .set("displayTimeUnit", "ms")
    }

    /// Write the Chrome trace to `path` (creating parent dirs).
    pub fn write_chrome_trace(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        std::fs::write(path, self.chrome_trace().render())
            .with_context(|| format!("writing trace to {}", path.display()))
    }
}

#[derive(Clone)]
struct TrackHandle {
    epoch: Instant,
    events: Arc<Mutex<Vec<Event>>>,
}

impl TrackHandle {
    fn push(
        &self,
        name: &str,
        ph: &'static str,
        start: Instant,
        end: Instant,
        args: &[(&'static str, f64)],
    ) {
        // spans from before the epoch (a caller's stale Instant) clamp
        // to 0 instead of going negative
        let ts_us = end
            .min(start)
            .max(self.epoch)
            .duration_since(self.epoch)
            .as_secs_f64()
            * 1e6;
        let dur_us = end.saturating_duration_since(start).as_secs_f64() * 1e6;
        self.events.lock().expect("track buffer").push(Event {
            name: name.to_string(),
            ph,
            ts_us,
            dur_us,
            args: args.to_vec(),
        });
    }
}

/// One thread's (or node's) event buffer. Cheap to clone; all ops are
/// no-ops when the parent tracer is disabled.
#[derive(Clone, Default)]
pub struct Track {
    inner: Option<TrackHandle>,
}

impl Track {
    /// A no-op track, for fields that may never see an installed
    /// tracer.
    pub fn disabled() -> Track {
        Track { inner: None }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Guard-based span: starts now, records when dropped. Scopes drop
    /// guards LIFO, so spans on one track nest properly by
    /// construction.
    pub fn span(&self, name: &'static str) -> Span {
        let Some(h) = &self.inner else {
            return Span { inner: None };
        };
        Span {
            inner: Some(SpanInner {
                handle: h.clone(),
                name,
                start: Instant::now(),
                args: Vec::new(),
            }),
        }
    }

    /// Explicit span between two timestamps the caller measured — the
    /// client-side per-socket submit→reply spans use this.
    pub fn record(
        &self,
        name: &'static str,
        start: Instant,
        end: Instant,
        args: &[(&'static str, f64)],
    ) {
        if let Some(h) = &self.inner {
            h.push(name, "X", start, end, args);
        }
    }

    /// Zero-duration instant event (admission decisions etc.).
    pub fn instant(&self, name: &'static str, args: &[(&'static str, f64)]) {
        if let Some(h) = &self.inner {
            let now = Instant::now();
            h.push(name, "i", now, now, args);
        }
    }
}

struct SpanInner {
    handle: TrackHandle,
    name: &'static str,
    start: Instant,
    args: Vec<(&'static str, f64)>,
}

/// Span guard returned by [`Track::span`]; records on drop.
pub struct Span {
    inner: Option<SpanInner>,
}

impl Span {
    /// Attach a numeric attribute (builder style; free when disabled).
    pub fn arg(mut self, key: &'static str, value: f64) -> Span {
        if let Some(i) = &mut self.inner {
            i.args.push((key, value));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(i) = self.inner.take() {
            i.handle.push(i.name, "X", i.start, Instant::now(), &i.args);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn disabled_tracer_is_inert() {
        let tr = Tracer::disabled();
        assert!(!tr.is_enabled());
        let t = tr.track("t");
        assert!(!t.is_enabled());
        let _s = t.span("x").arg("k", 1.0);
        t.instant("i", &[]);
        t.record("r", Instant::now(), Instant::now(), &[]);
        let j = tr.chrome_trace().render();
        // only the process_name metadata event
        assert!(j.contains("traceEvents"));
        assert!(!j.contains("thread_name"));
    }

    #[test]
    fn spans_and_instants_export() {
        let tr = Tracer::enabled();
        let t = tr.track("worker");
        {
            let _a = t.span("outer").arg("layer", 3.0);
            let _b = t.span("inner");
        }
        t.instant("mark", &[("x", 1.0)]);
        let s = tr.chrome_trace().render();
        assert!(s.contains("\"thread_name\""));
        assert!(s.contains("\"worker\""));
        assert!(s.contains("\"outer\""));
        assert!(s.contains("\"inner\""));
        assert!(s.contains("\"mark\""));
        assert!(s.contains("\"layer\":3"));
        // the export must itself be valid JSON
        let parsed = Json::parse(&s).expect("chrome trace parses");
        let events = parsed
            .get("traceEvents")
            .and_then(Json::as_arr)
            .expect("traceEvents array");
        // process_name + thread_name + outer + inner + mark
        assert_eq!(events.len(), 5);
    }

    /// Random nesting: guards drop LIFO per scope, so the flushed
    /// events must form a valid nesting (every pair of spans on one
    /// track is either disjoint or contained) and the export must be a
    /// parseable Chrome trace.
    #[test]
    fn prop_span_nesting_is_valid_chrome_trace() {
        prop::check("tracer-nesting", 30, |g| {
            let tr = Tracer::enabled();
            let track = tr.track("t");
            let mut expected = 0usize;
            // random recursive span tree, depth ≤ 4
            fn descend(
                t: &Track,
                g: &mut prop::Gen,
                depth: usize,
                count: &mut usize,
            ) {
                let kids = g.usize_in(0, 3);
                for _ in 0..kids {
                    let _s = t.span("n");
                    *count += 1;
                    if depth < 4 {
                        descend(t, g, depth + 1, count);
                    }
                }
            }
            descend(&track, g, 0, &mut expected);
            let parsed =
                Json::parse(&tr.chrome_trace().render()).expect("parses");
            let events = parsed
                .get("traceEvents")
                .and_then(Json::as_arr)
                .expect("traceEvents");
            let mut spans: Vec<(f64, f64)> = events
                .iter()
                .filter(|e| {
                    e.get("ph").and_then(Json::as_str) == Some("X")
                })
                .map(|e| {
                    let ts = e.get("ts").and_then(Json::as_f64).unwrap();
                    let dur = e.get("dur").and_then(Json::as_f64).unwrap();
                    (ts, ts + dur)
                })
                .collect();
            assert_eq!(spans.len(), expected);
            // sort by start asc, end desc: parents precede children
            spans.sort_by(|a, b| {
                a.0.total_cmp(&b.0).then(b.1.total_cmp(&a.1))
            });
            let eps = 0.5; // µs: clock granularity slack
            let mut stack: Vec<(f64, f64)> = Vec::new();
            for (s, e) in spans {
                while let Some(&(_, te)) = stack.last() {
                    if s >= te - eps {
                        stack.pop();
                    } else {
                        break;
                    }
                }
                if let Some(&(ts, te)) = stack.last() {
                    assert!(
                        s >= ts - eps && e <= te + eps,
                        "span ({s}, {e}) straddles ({ts}, {te})"
                    );
                }
                stack.push((s, e));
            }
        });
    }

    #[test]
    fn record_clamps_stale_starts() {
        let before = Instant::now();
        let tr = Tracer::enabled();
        let t = tr.track("t");
        t.record("old", before, Instant::now(), &[]);
        let parsed = Json::parse(&tr.chrome_trace().render()).unwrap();
        let ts = parsed.get("traceEvents").and_then(Json::as_arr).unwrap()
            [2]
        .get("ts")
        .and_then(Json::as_f64)
        .unwrap();
        assert!(ts >= 0.0);
    }
}
