//! Wire-level counters: per-connection frame/byte totals and per-node
//! attend/error/drift accounting.
//!
//! [`TransportCounters`] is maintained inside each `net::Transport`
//! impl (loopback and TCP) — every framed send/recv bumps it, so the
//! numbers are ground truth for what crossed the wire. `RemotePool`
//! aggregates them per node into [`NetStats`] together with attend-op
//! and error counts and the **drift detector**: for every attend
//! request (and its outputs response) the pool computes the
//! `transport::LinkModel`-modeled activation payload bytes and the
//! measured payload bytes (frame length minus the deterministic codec
//! framing overhead); any mismatch increments `drift_events`. This
//! promotes PR 5's pinned-bytes test discipline into an always-on
//! runtime check — if the codec or the link model changes shape, live
//! runs notice, not just the unit test.

use crate::util::json::Json;

/// Frames and bytes through one connection, both directions.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportCounters {
    pub frames_sent: u64,
    pub bytes_sent: u64,
    pub frames_recv: u64,
    pub bytes_recv: u64,
}

impl TransportCounters {
    pub fn on_send(&mut self, frame_len: usize) {
        self.frames_sent += 1;
        self.bytes_sent += frame_len as u64;
    }

    pub fn on_recv(&mut self, frame_len: usize) {
        self.frames_recv += 1;
        self.bytes_recv += frame_len as u64;
    }
}

/// One remote node's wire accounting, as surfaced by
/// `AttendBackend::net_stats`.
#[derive(Clone, Debug, Default)]
pub struct NetStats {
    pub node: usize,
    pub label: String,
    /// Frame/byte totals from the node's transport (last snapshot if
    /// the node is dead).
    pub transport: TransportCounters,
    /// Attend RPCs submitted to this node.
    pub attend_ops: u64,
    /// Errors observed on this node (refusals, transport failures).
    pub errors: u64,
    /// LinkModel-modeled activation payload bytes sent (QKV legs).
    pub modeled_payload_sent: u64,
    /// Measured activation payload bytes sent (frame − framing overhead).
    pub measured_payload_sent: u64,
    /// Modeled activation payload bytes received (O legs).
    pub modeled_payload_recv: u64,
    /// Measured activation payload bytes received.
    pub measured_payload_recv: u64,
    /// Times measured ≠ modeled; nonzero means the codec and the
    /// LinkModel disagree about message shape.
    pub drift_events: u64,
}

impl NetStats {
    /// True when every measured byte matched the model.
    pub fn drift_free(&self) -> bool {
        self.drift_events == 0
            && self.modeled_payload_sent == self.measured_payload_sent
            && self.modeled_payload_recv == self.measured_payload_recv
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("node", self.node)
            .set("label", self.label.as_str())
            .set("frames_sent", self.transport.frames_sent)
            .set("bytes_sent", self.transport.bytes_sent)
            .set("frames_recv", self.transport.frames_recv)
            .set("bytes_recv", self.transport.bytes_recv)
            .set("attend_ops", self.attend_ops)
            .set("errors", self.errors)
            .set("modeled_payload_sent", self.modeled_payload_sent)
            .set("measured_payload_sent", self.measured_payload_sent)
            .set("modeled_payload_recv", self.modeled_payload_recv)
            .set("measured_payload_recv", self.measured_payload_recv)
            .set("drift_events", self.drift_events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut c = TransportCounters::default();
        c.on_send(100);
        c.on_send(50);
        c.on_recv(7);
        assert_eq!(c.frames_sent, 2);
        assert_eq!(c.bytes_sent, 150);
        assert_eq!(c.frames_recv, 1);
        assert_eq!(c.bytes_recv, 7);
    }

    #[test]
    fn drift_free_requires_exact_match() {
        let mut s = NetStats {
            modeled_payload_sent: 10,
            measured_payload_sent: 10,
            ..NetStats::default()
        };
        assert!(s.drift_free());
        s.drift_events = 1;
        assert!(!s.drift_free());
        s.drift_events = 0;
        s.measured_payload_recv = 4;
        assert!(!s.drift_free());
        let j = s.to_json().render();
        assert!(j.contains("\"drift_events\":0"));
    }
}
