//! Wire-level counters: per-connection frame/byte totals and per-node
//! attend/error/drift accounting.
//!
//! [`TransportCounters`] is maintained inside each `net::Transport`
//! impl (loopback and TCP) — every framed send/recv bumps it, so the
//! numbers are ground truth for what crossed the wire. `RemotePool`
//! aggregates them per node into [`NetStats`] together with attend-op
//! and error counts and the **drift detector**: for every attend
//! request (and its outputs response) the pool computes the
//! `transport::LinkModel`-modeled activation payload bytes and the
//! measured payload bytes (frame length minus the deterministic codec
//! framing overhead); any mismatch increments `drift_events`. This
//! promotes PR 5's pinned-bytes test discipline into an always-on
//! runtime check — if the codec or the link model changes shape, live
//! runs notice, not just the unit test.

use std::time::Duration;

use crate::metrics::Histogram;
use crate::util::json::Json;

/// Frames and bytes through one connection, both directions.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportCounters {
    pub frames_sent: u64,
    pub bytes_sent: u64,
    pub frames_recv: u64,
    pub bytes_recv: u64,
}

impl TransportCounters {
    pub fn on_send(&mut self, frame_len: usize) {
        self.frames_sent += 1;
        self.bytes_sent += frame_len as u64;
    }

    pub fn on_recv(&mut self, frame_len: usize) {
        self.frames_recv += 1;
        self.bytes_recv += frame_len as u64;
    }
}

/// Live performance profile of one R-node, maintained client-side from
/// the submit→reply timing of every gathered attend. This is the
/// MEASURED per-node throughput the heterogeneity-aware planner needs
/// (`perfmodel::Planner::from_measured_profiles`): EWMA rates adapt to
/// drifting node speed, the service-time histogram captures the tail a
/// mean would hide, and the queue depth shows standing backlog.
#[derive(Clone, Debug, Default)]
pub struct NodeProfile {
    /// EWMA of attended token rows per second of service time.
    pub tokens_per_s: f64,
    /// EWMA of streamed activation payload bytes per second.
    pub bytes_per_s: f64,
    /// Per-attend submit→reply service time (p50/p99 via percentiles).
    pub service: Histogram,
    /// Attends in flight right now (submitted, not yet gathered).
    pub queue_depth: usize,
    /// Highest queue depth ever observed.
    pub peak_queue_depth: usize,
}

/// EWMA smoothing factor: ~5 observations of memory, fast enough to
/// follow a node that slows under co-located load.
const PROFILE_ALPHA: f64 = 0.2;

impl NodeProfile {
    /// Record one gathered attend: `rows` token rows and `bytes` of
    /// activation payload served in `service` wall time.
    pub fn observe(&mut self, rows: usize, bytes: u64, service: Duration) {
        let secs = service.as_secs_f64().max(1e-9);
        self.service.record_secs(service.as_secs_f64());
        let tok_rate = rows as f64 / secs;
        let byte_rate = bytes as f64 / secs;
        if self.service.count() == 1 {
            self.tokens_per_s = tok_rate;
            self.bytes_per_s = byte_rate;
        } else {
            self.tokens_per_s +=
                PROFILE_ALPHA * (tok_rate - self.tokens_per_s);
            self.bytes_per_s +=
                PROFILE_ALPHA * (byte_rate - self.bytes_per_s);
        }
    }

    /// Bump the in-flight count at submit time.
    pub fn on_submit(&mut self) {
        self.queue_depth += 1;
        self.peak_queue_depth = self.peak_queue_depth.max(self.queue_depth);
    }

    /// Drop the in-flight count at gather time.
    pub fn on_gather(&mut self) {
        self.queue_depth = self.queue_depth.saturating_sub(1);
    }

    /// Attends observed so far.
    pub fn samples(&self) -> u64 {
        self.service.count()
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("tokens_per_s", self.tokens_per_s)
            .set("bytes_per_s", self.bytes_per_s)
            .set("service_p50_us", self.service.percentile_us(0.50))
            .set("service_p99_us", self.service.percentile_us(0.99))
            .set("samples", self.samples())
            .set("queue_depth", self.queue_depth)
            .set("peak_queue_depth", self.peak_queue_depth)
    }
}

/// One remote node's wire accounting, as surfaced by
/// `AttendBackend::net_stats`.
#[derive(Clone, Debug, Default)]
pub struct NetStats {
    pub node: usize,
    pub label: String,
    /// Frame/byte totals from the node's transport (last snapshot if
    /// the node is dead).
    pub transport: TransportCounters,
    /// Attend RPCs submitted to this node.
    pub attend_ops: u64,
    /// Errors observed on this node (refusals, transport failures).
    pub errors: u64,
    /// LinkModel-modeled activation payload bytes sent (QKV legs).
    pub modeled_payload_sent: u64,
    /// Measured activation payload bytes sent (frame − framing overhead).
    pub measured_payload_sent: u64,
    /// Modeled activation payload bytes received (O legs).
    pub modeled_payload_recv: u64,
    /// Measured activation payload bytes received.
    pub measured_payload_recv: u64,
    /// Times measured ≠ modeled; nonzero means the codec and the
    /// LinkModel disagree about message shape.
    pub drift_events: u64,
    /// Live measured performance profile (EWMA throughput, service-time
    /// percentiles, queue depth) — the planner's measurement input.
    pub profile: NodeProfile,
}

impl NetStats {
    /// True when every measured byte matched the model.
    pub fn drift_free(&self) -> bool {
        self.drift_events == 0
            && self.modeled_payload_sent == self.measured_payload_sent
            && self.modeled_payload_recv == self.measured_payload_recv
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("node", self.node)
            .set("label", self.label.as_str())
            .set("frames_sent", self.transport.frames_sent)
            .set("bytes_sent", self.transport.bytes_sent)
            .set("frames_recv", self.transport.frames_recv)
            .set("bytes_recv", self.transport.bytes_recv)
            .set("attend_ops", self.attend_ops)
            .set("errors", self.errors)
            .set("modeled_payload_sent", self.modeled_payload_sent)
            .set("measured_payload_sent", self.measured_payload_sent)
            .set("modeled_payload_recv", self.modeled_payload_recv)
            .set("measured_payload_recv", self.measured_payload_recv)
            .set("drift_events", self.drift_events)
            .set("profile", self.profile.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut c = TransportCounters::default();
        c.on_send(100);
        c.on_send(50);
        c.on_recv(7);
        assert_eq!(c.frames_sent, 2);
        assert_eq!(c.bytes_sent, 150);
        assert_eq!(c.frames_recv, 1);
        assert_eq!(c.bytes_recv, 7);
    }

    #[test]
    fn node_profile_ewma_and_queue_depth() {
        let mut p = NodeProfile::default();
        assert_eq!(p.samples(), 0);
        // first observation seeds the EWMA exactly
        p.observe(100, 1000, Duration::from_millis(10));
        assert!((p.tokens_per_s - 10_000.0).abs() < 1.0, "{}", p.tokens_per_s);
        assert!((p.bytes_per_s - 100_000.0).abs() < 10.0, "{}", p.bytes_per_s);
        // a 2× faster observation moves the EWMA by alpha of the gap
        p.observe(200, 2000, Duration::from_millis(10));
        assert!(
            p.tokens_per_s > 10_000.0 && p.tokens_per_s < 20_000.0,
            "{}",
            p.tokens_per_s
        );
        assert_eq!(p.samples(), 2);
        assert!(p.service.percentile_us(0.99) >= p.service.percentile_us(0.5));

        p.on_submit();
        p.on_submit();
        assert_eq!(p.queue_depth, 2);
        assert_eq!(p.peak_queue_depth, 2);
        p.on_gather();
        p.on_gather();
        p.on_gather(); // saturates, never underflows
        assert_eq!(p.queue_depth, 0);
        assert_eq!(p.peak_queue_depth, 2);
        let j = p.to_json().render();
        assert!(j.contains("tokens_per_s"));
    }

    #[test]
    fn drift_free_requires_exact_match() {
        let mut s = NetStats {
            modeled_payload_sent: 10,
            measured_payload_sent: 10,
            ..NetStats::default()
        };
        assert!(s.drift_free());
        s.drift_events = 1;
        assert!(!s.drift_free());
        s.drift_events = 0;
        s.measured_payload_recv = 4;
        assert!(!s.drift_free());
        let j = s.to_json().render();
        assert!(j.contains("\"drift_events\":0"));
    }
}
