//! LIVE metrics registry — the "what is happening *right now*" half of
//! the observability stack (the post-hoc half is [`super::tracer`]).
//!
//! A [`Metrics`] handle is a process-wide registry of **labeled
//! counters, gauges, and histograms** plus fixed-capacity **time-series
//! ring buffers** ([`RingSeries`]) that callers sample on their own
//! cadence (the serve engine and pipeline sample once per step). It
//! follows the exact `Option<Arc<_>>` discipline of
//! [`crate::obs::Tracer`]: a disabled handle is a single branch per
//! call, so instrumentation can stay in hot paths unconditionally.
//!
//! * [`Metrics::global`] is the process singleton, enabled once per
//!   process iff `FASTDECODE_METRICS` is set to something other than
//!   `0`/`""` — mirroring `FASTDECODE_TRACE`.
//! * Keys are rendered Prometheus-style up front:
//!   `name{k1="v1",k2="v2"}`, built from a `&[(&str, &str)]` label set
//!   (labels are sorted by the caller's ordering; pass them in a fixed
//!   order for stable keys).
//! * Histograms reuse [`crate::metrics::Histogram`] — one log-bucketed
//!   percentile implementation in the repo, one merge path.
//! * Export is [`Metrics::prometheus_text`] (text exposition) and
//!   [`Metrics::to_json`] (via `util::json`) — both are point-in-time
//!   snapshots taken under the registry lock.
//!
//! Mutex poisoning is deliberately ignored (`into_inner` on a poisoned
//! lock): metrics are advisory, and a panicking instrumented thread
//! must never take the rest of the process's observability with it.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::metrics::Histogram;
use crate::util::json::Json;

/// Default capacity of a time-series ring buffer. Power of two so the
/// halving downsample walks through clean sizes.
pub const DEFAULT_SERIES_CAP: usize = 256;

/// Fixed-capacity time series: `(ts_us, value)` samples with the
/// timestamp in microseconds since the registry start. When a push
/// exceeds the capacity the series **downsamples by keeping every
/// second sample** (plus the newest), so the buffer always spans the
/// full recording window at degrading resolution rather than
/// forgetting the oldest half. Downsampling preserves the FIRST and
/// LAST samples and keeps timestamps monotone (any subsequence of a
/// monotone sequence is monotone) — pinned by property test.
#[derive(Clone, Debug)]
pub struct RingSeries {
    cap: usize,
    samples: Vec<(f64, f64)>,
}

impl RingSeries {
    pub fn new(cap: usize) -> RingSeries {
        RingSeries {
            cap: cap.max(2),
            samples: Vec::new(),
        }
    }

    pub fn push(&mut self, ts_us: f64, value: f64) {
        self.samples.push((ts_us, value));
        if self.samples.len() > self.cap {
            self.downsample();
        }
    }

    /// Keep indices 0, 2, 4, … plus the last sample (the newest point
    /// must survive — it is what a live poller reads).
    fn downsample(&mut self) {
        let n = self.samples.len();
        if n < 3 {
            return;
        }
        let last = self.samples[n - 1];
        let mut kept: Vec<(f64, f64)> =
            self.samples.iter().copied().step_by(2).collect();
        if (n - 1) % 2 != 0 {
            kept.push(last);
        }
        self.samples = kept;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn samples(&self) -> &[(f64, f64)] {
        &self.samples
    }

    fn to_json(&self) -> Json {
        let ts: Vec<f64> = self.samples.iter().map(|s| s.0).collect();
        let vs: Vec<f64> = self.samples.iter().map(|s| s.1).collect();
        Json::obj()
            .set("capacity", self.cap)
            .set("ts_us", ts)
            .set("values", vs)
    }
}

#[derive(Default)]
struct State {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
    series: BTreeMap<String, RingSeries>,
}

struct Inner {
    start: Instant,
    state: Mutex<State>,
}

/// Cheap-to-clone handle to the live metrics registry (or to nothing,
/// when disabled — every op is then a single branch).
#[derive(Clone, Default)]
pub struct Metrics {
    inner: Option<Arc<Inner>>,
}

/// Render `name{k1="v1",k2="v2"}`; a bare `name` when `labels` is
/// empty. This is both the storage key and the Prometheus series name.
pub fn metric_key(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut k = String::with_capacity(name.len() + 16 * labels.len());
    k.push_str(name);
    k.push('{');
    for (i, (lk, lv)) in labels.iter().enumerate() {
        if i > 0 {
            k.push(',');
        }
        k.push_str(lk);
        k.push_str("=\"");
        k.push_str(lv);
        k.push('"');
    }
    k.push('}');
    k
}

/// Insert `suffix` into a rendered key before its label block:
/// `h{n="0"}` + `_p99_us` → `h_p99_us{n="0"}`.
fn suffixed(key: &str, suffix: &str) -> String {
    match key.find('{') {
        Some(i) => format!("{}{}{}", &key[..i], suffix, &key[i..]),
        None => format!("{key}{suffix}"),
    }
}

impl Metrics {
    /// A no-op registry: every op is a single branch.
    pub fn disabled() -> Metrics {
        Metrics { inner: None }
    }

    /// An active registry; series timestamps are relative to now.
    pub fn enabled() -> Metrics {
        Metrics {
            inner: Some(Arc::new(Inner {
                start: Instant::now(),
                state: Mutex::new(State::default()),
            })),
        }
    }

    /// Enabled iff `FASTDECODE_METRICS` is set to something other than
    /// `0`/`""` (checked once per process) — a fresh registry per call;
    /// use [`Metrics::global`] for the process-wide one.
    pub fn from_env() -> Metrics {
        static ON: OnceLock<bool> = OnceLock::new();
        let on = *ON.get_or_init(|| {
            std::env::var("FASTDECODE_METRICS")
                .map(|v| !v.is_empty() && v != "0")
                .unwrap_or(false)
        });
        if on {
            Metrics::enabled()
        } else {
            Metrics::disabled()
        }
    }

    /// The process-wide registry (a clone of one shared handle),
    /// enabled by `FASTDECODE_METRICS`. All built-in instrumentation
    /// (serve engine, pipeline, remote pool, KV cache) records here.
    pub fn global() -> Metrics {
        static GLOBAL: OnceLock<Metrics> = OnceLock::new();
        GLOBAL.get_or_init(Metrics::from_env).clone()
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    fn lock<'a>(inner: &'a Arc<Inner>) -> MutexGuard<'a, State> {
        match inner.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Microseconds since the registry was created (series time base).
    pub fn elapsed_us(&self) -> f64 {
        match &self.inner {
            Some(inner) => inner.start.elapsed().as_secs_f64() * 1e6,
            None => 0.0,
        }
    }

    /// Add `delta` to a monotonically increasing counter.
    pub fn inc(&self, name: &str, labels: &[(&str, &str)], delta: u64) {
        let Some(inner) = &self.inner else { return };
        let key = metric_key(name, labels);
        *Self::lock(inner).counters.entry(key).or_insert(0) += delta;
    }

    /// Set a gauge to its latest value (last write wins).
    pub fn set_gauge(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        let Some(inner) = &self.inner else { return };
        let key = metric_key(name, labels);
        Self::lock(inner).gauges.insert(key, v);
    }

    /// Record a duration (µs) into a labeled histogram
    /// ([`crate::metrics::Histogram`] — the repo's one percentile
    /// implementation).
    pub fn observe_us(&self, name: &str, labels: &[(&str, &str)], us: f64) {
        let Some(inner) = &self.inner else { return };
        let key = metric_key(name, labels);
        Self::lock(inner)
            .hists
            .entry(key)
            .or_insert_with(Histogram::new)
            .record_us(us);
    }

    pub fn observe_secs(&self, name: &str, labels: &[(&str, &str)], s: f64) {
        self.observe_us(name, labels, s * 1e6);
    }

    /// Append a time-series sample (timestamped now) to a ring buffer
    /// of [`DEFAULT_SERIES_CAP`]. Callers pick the cadence — one sample
    /// per step is the intended interval for step-level series.
    pub fn sample(&self, name: &str, labels: &[(&str, &str)], v: f64) {
        self.sample_with_cap(name, labels, v, DEFAULT_SERIES_CAP);
    }

    pub fn sample_with_cap(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        v: f64,
        cap: usize,
    ) {
        let Some(inner) = &self.inner else { return };
        let ts_us = inner.start.elapsed().as_secs_f64() * 1e6;
        let key = metric_key(name, labels);
        Self::lock(inner)
            .series
            .entry(key)
            .or_insert_with(|| RingSeries::new(cap))
            .push(ts_us, v);
    }

    /// Point-in-time read of one counter (test / poll helper).
    pub fn counter_value(
        &self,
        name: &str,
        labels: &[(&str, &str)],
    ) -> Option<u64> {
        let inner = self.inner.as_ref()?;
        let key = metric_key(name, labels);
        Self::lock(inner).counters.get(&key).copied()
    }

    /// Point-in-time read of one gauge (test / poll helper).
    pub fn gauge_value(
        &self,
        name: &str,
        labels: &[(&str, &str)],
    ) -> Option<f64> {
        let inner = self.inner.as_ref()?;
        let key = metric_key(name, labels);
        Self::lock(inner).gauges.get(&key).copied()
    }

    /// Prometheus-style text exposition: one `name{labels} value` line
    /// per counter/gauge; histograms expand to `_count` / `_mean_us` /
    /// `_p50_us` / `_p99_us` / `_max_us` lines. Empty string when
    /// disabled.
    pub fn prometheus_text(&self) -> String {
        let Some(inner) = &self.inner else {
            return String::new();
        };
        let st = Self::lock(inner);
        let mut out = String::new();
        for (k, v) in &st.counters {
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, v) in &st.gauges {
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, h) in &st.hists {
            if h.count() == 0 {
                continue;
            }
            out.push_str(&format!("{} {}\n", suffixed(k, "_count"), h.count()));
            out.push_str(&format!(
                "{} {:.3}\n",
                suffixed(k, "_mean_us"),
                h.mean_us()
            ));
            out.push_str(&format!(
                "{} {:.3}\n",
                suffixed(k, "_p50_us"),
                h.percentile_us(0.50)
            ));
            out.push_str(&format!(
                "{} {:.3}\n",
                suffixed(k, "_p99_us"),
                h.percentile_us(0.99)
            ));
            out.push_str(&format!(
                "{} {:.3}\n",
                suffixed(k, "_max_us"),
                h.max_us()
            ));
        }
        out
    }

    /// JSON snapshot of the whole registry (counters, gauges,
    /// histogram summaries via `Histogram::to_json_ms`, and the full
    /// ring-buffer series). `Json::Null` when disabled.
    pub fn to_json(&self) -> Json {
        let Some(inner) = &self.inner else {
            return Json::Null;
        };
        let st = Self::lock(inner);
        let mut counters = Json::obj();
        for (k, v) in &st.counters {
            counters = counters.set(k.as_str(), *v);
        }
        let mut gauges = Json::obj();
        for (k, v) in &st.gauges {
            gauges = gauges.set(k.as_str(), *v);
        }
        let mut hists = Json::obj();
        for (k, h) in &st.hists {
            hists = hists.set(k.as_str(), h.to_json_ms());
        }
        let mut series = Json::obj();
        for (k, s) in &st.series {
            series = series.set(k.as_str(), s.to_json());
        }
        Json::obj()
            .set("uptime_us", inner.start.elapsed().as_secs_f64() * 1e6)
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists)
            .set("series", series)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn disabled_registry_is_inert() {
        let m = Metrics::disabled();
        assert!(!m.is_enabled());
        m.inc("c", &[], 3);
        m.set_gauge("g", &[], 1.0);
        m.observe_us("h", &[], 5.0);
        m.sample("s", &[], 1.0);
        assert_eq!(m.counter_value("c", &[]), None);
        assert_eq!(m.gauge_value("g", &[]), None);
        assert_eq!(m.prometheus_text(), "");
        assert!(matches!(m.to_json(), Json::Null));
    }

    #[test]
    fn keys_render_prometheus_style() {
        assert_eq!(metric_key("tok_per_s", &[]), "tok_per_s");
        assert_eq!(
            metric_key("inflight", &[("node", "0"), ("op", "attend")]),
            "inflight{node=\"0\",op=\"attend\"}"
        );
        assert_eq!(
            suffixed("h{n=\"0\"}", "_p99_us"),
            "h_p99_us{n=\"0\"}"
        );
        assert_eq!(suffixed("h", "_count"), "h_count");
    }

    #[test]
    fn counters_gauges_hists_roundtrip_through_exports() {
        let m = Metrics::enabled();
        m.inc("frames", &[("node", "0")], 2);
        m.inc("frames", &[("node", "0")], 3);
        m.set_gauge("queue_depth", &[], 7.0);
        for us in [100.0, 200.0, 300.0] {
            m.observe_us("service", &[("node", "1")], us);
        }
        assert_eq!(m.counter_value("frames", &[("node", "0")]), Some(5));
        assert_eq!(m.gauge_value("queue_depth", &[]), Some(7.0));

        let text = m.prometheus_text();
        assert!(text.contains("frames{node=\"0\"} 5"), "{text}");
        assert!(text.contains("queue_depth 7"), "{text}");
        assert!(text.contains("service_count{node=\"1\"} 3"), "{text}");
        assert!(text.contains("service_p99_us{node=\"1\"}"), "{text}");

        let doc = Json::parse(&m.to_json().render()).unwrap();
        let counters = doc.get("counters").unwrap();
        assert_eq!(
            counters.get("frames{node=\"0\"}").and_then(|j| j.as_f64()),
            Some(5.0)
        );
        let h = doc.get("histograms").unwrap();
        assert!(h.get("service{node=\"1\"}").is_some());
    }

    /// Satellite property test: a snapshot under concurrent recording
    /// never loses counts — the exported counter equals the sum of
    /// per-thread increments.
    #[test]
    fn prop_concurrent_counter_increments_never_lost() {
        prop::check("metrics_concurrent_counts", 8, |g| {
            let threads = g.usize_in(2, 6);
            let per_thread = g.usize_in(50, 400);
            let m = Metrics::enabled();
            std::thread::scope(|s| {
                for t in 0..threads {
                    let m = m.clone();
                    s.spawn(move || {
                        for i in 0..per_thread {
                            m.inc("hits", &[("kind", "prop")], 1);
                            // interleave snapshot reads with writes
                            if t == 0 && i % 64 == 0 {
                                let _ = m.prometheus_text();
                            }
                        }
                    });
                }
            });
            assert_eq!(
                m.counter_value("hits", &[("kind", "prop")]),
                Some((threads * per_thread) as u64)
            );
        });
    }

    /// Satellite property test: ring-buffer downsampling preserves the
    /// first and last samples and monotone timestamps, and never
    /// exceeds capacity + 1.
    #[test]
    fn prop_ring_series_downsampling_invariants() {
        prop::check("ring_series_downsample", 64, |g| {
            let cap = g.usize_in(2, 64);
            let n = g.usize_in(1, 1000);
            let mut rs = RingSeries::new(cap);
            let mut ts = 0.0f64;
            for i in 0..n {
                ts += g.f32_in(0.0, 10.0) as f64;
                rs.push(ts, i as f64);
            }
            let s = rs.samples();
            assert!(!s.is_empty());
            // first and last survive every downsample
            assert_eq!(s[0].1, 0.0, "first sample lost");
            assert_eq!(s[s.len() - 1].1, (n - 1) as f64, "last sample lost");
            assert_eq!(s[s.len() - 1].0, ts);
            // monotone (non-decreasing) timestamps
            for w in s.windows(2) {
                assert!(w[0].0 <= w[1].0, "timestamps went backwards");
            }
            // bounded: keeping the newest after a halving may briefly
            // leave cap/2 + 1 entries; never more than cap + 1 overall
            assert!(s.len() <= rs.capacity() + 1, "len {} cap {}", s.len(), cap);
        });
    }

    #[test]
    fn global_is_disabled_without_env_and_shared() {
        // CI never sets FASTDECODE_METRICS for the test binary, so the
        // global must be inert — and repeated calls share the handle.
        let a = Metrics::global();
        let b = Metrics::global();
        assert_eq!(a.is_enabled(), b.is_enabled());
        if a.is_enabled() {
            a.inc("global_shared_probe", &[], 1);
            assert_eq!(b.counter_value("global_shared_probe", &[]), Some(1));
        }
    }
}
