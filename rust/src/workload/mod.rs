//! Workload generation: synthetic request traces for the serving
//! examples and the online-admission experiments (no public production
//! trace is available — DESIGN.md §2).

use crate::util::Rng;

/// One generation request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time offset from trace start, seconds.
    pub arrival_s: f64,
    pub prompt: Vec<i32>,
    /// Tokens to generate.
    pub target_len: usize,
}

/// Trace generator: Poisson arrivals, uniform prompt lengths, fixed or
/// jittered target lengths, optional shared system-prompt prefix.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    pub seed: u64,
    /// Mean arrivals per second.
    pub rate: f64,
    pub prompt_len: (usize, usize),
    pub target_len: (usize, usize),
    pub vocab: usize,
    pub count: usize,
    /// Length of ONE shared prefix (a common system prompt) prepended
    /// to a `share_prob` fraction of prompts; 0 disables sharing and
    /// keeps the generated trace byte-identical to what this generator
    /// produced before prefixes existed.
    pub prefix_len: usize,
    /// Probability a request carries the shared prefix (ignored when
    /// `prefix_len` is 0).
    pub share_prob: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 1,
            rate: 16.0,
            prompt_len: (4, 16),
            target_len: (32, 64),
            vocab: 256,
            count: 64,
            prefix_len: 0,
            share_prob: 0.0,
        }
    }
}

impl TraceConfig {
    /// Chat-style mix: most requests open with the same system prompt,
    /// so a prefix-sharing cache stores (and recomputes) it once.
    /// `prompt_len` here is the per-request tail AFTER the prefix.
    pub fn shared_prefix_mix(seed: u64) -> TraceConfig {
        TraceConfig {
            seed,
            prefix_len: 12,
            share_prob: 0.75,
            prompt_len: (2, 6),
            ..TraceConfig::default()
        }
    }

    /// Long-context mix: long prompts, short generations
    /// (summarization-style) — stresses chunked prefill and per-step
    /// prefill burst size rather than decode residency.
    pub fn long_context_mix(seed: u64) -> TraceConfig {
        TraceConfig {
            seed,
            rate: 8.0,
            prompt_len: (48, 96),
            target_len: (4, 8),
            count: 16,
            ..TraceConfig::default()
        }
    }
}

pub fn generate_trace(cfg: &TraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    // drawn FIRST so the draw order (and hence the whole trace) with
    // prefix_len == 0 is unchanged from the pre-prefix generator
    let shared: Vec<i32> = (0..cfg.prefix_len)
        .map(|_| rng.range_usize(0, cfg.vocab) as i32)
        .collect();
    let mut t = 0.0;
    (0..cfg.count as u64)
        .map(|id| {
            t += rng.exponential(cfg.rate);
            let plen = rng.range_usize(cfg.prompt_len.0, cfg.prompt_len.1 + 1);
            let tlen = rng.range_usize(cfg.target_len.0, cfg.target_len.1 + 1);
            // && short-circuits: the default path consumes no extra draw
            let share = cfg.prefix_len > 0 && rng.next_f64() < cfg.share_prob;
            let mut prompt: Vec<i32> =
                if share { shared.clone() } else { Vec::new() };
            prompt
                .extend((0..plen).map(|_| rng.range_usize(0, cfg.vocab) as i32));
            Request {
                id,
                arrival_s: t,
                prompt,
                target_len: tlen,
            }
        })
        .collect()
}

/// Fixed-shape batch workload (the paper's §6 throughput benchmark:
/// short prompt, generate to a fixed total length).
pub fn fixed_batch(
    batch: usize,
    prompt_len: usize,
    vocab: usize,
    seed: u64,
) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..batch)
        .map(|_| {
            (0..prompt_len)
                .map(|_| rng.range_usize(0, vocab) as i32)
                .collect()
        })
        .collect()
}

/// Degenerate trace for lockstep tests: `count` equal-shape requests
/// all arriving at t = 0 (continuous batching over this trace must be
/// bit-identical to a fixed-batch `generate` run).
pub fn lockstep_trace(
    count: usize,
    prompt_len: usize,
    target_len: usize,
    vocab: usize,
    seed: u64,
) -> Vec<Request> {
    fixed_batch(count, prompt_len, vocab, seed)
        .into_iter()
        .enumerate()
        .map(|(i, prompt)| Request {
            id: i as u64,
            arrival_s: 0.0,
            prompt,
            target_len,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let cfg = TraceConfig::default();
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert_eq!(a.len(), cfg.count);
    }

    #[test]
    fn lengths_respect_bounds() {
        let cfg = TraceConfig {
            prompt_len: (3, 5),
            target_len: (10, 12),
            ..Default::default()
        };
        for r in generate_trace(&cfg) {
            assert!((3..=5).contains(&r.prompt.len()));
            assert!((10..=12).contains(&r.target_len));
            assert!(r.prompt.iter().all(|&t| (t as usize) < cfg.vocab));
        }
    }

    #[test]
    fn arrival_rate_roughly_matches() {
        let cfg = TraceConfig {
            rate: 100.0,
            count: 2000,
            ..Default::default()
        };
        let trace = generate_trace(&cfg);
        let span = trace.last().unwrap().arrival_s;
        let rate = cfg.count as f64 / span;
        assert!((rate / cfg.rate - 1.0).abs() < 0.15, "rate {rate}");
    }

    #[test]
    fn fixed_batch_shapes() {
        let b = fixed_batch(4, 7, 100, 3);
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|p| p.len() == 7));
        assert_ne!(b[0], b[1]); // prompts differ
    }

    /// The MEAN INTER-ARRIVAL GAP itself (not just count/span) matches
    /// 1/rate, and the gaps are genuinely exponential-ish: strictly
    /// positive with substantial spread (a constant-gap generator would
    /// fail the variance floor).
    #[test]
    fn mean_inter_arrival_matches_inverse_rate() {
        let cfg = TraceConfig {
            rate: 50.0,
            count: 4000,
            seed: 7,
            ..Default::default()
        };
        let trace = generate_trace(&cfg);
        let gaps: Vec<f64> = std::iter::once(trace[0].arrival_s)
            .chain(trace.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s))
            .collect();
        assert!(gaps.iter().all(|&g| g >= 0.0));
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let want = 1.0 / cfg.rate;
        assert!(
            (mean / want - 1.0).abs() < 0.1,
            "mean gap {mean} vs 1/rate {want}"
        );
        // exponential: std ≈ mean (coefficient of variation ≈ 1)
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
            / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.15, "coefficient of variation {cv}");
    }

    /// With `prefix_len == 0` the prefix fields must be inert: the
    /// trace is identical whatever `share_prob` says (no extra rng
    /// draw), so every pre-prefix caller sees byte-identical traces.
    #[test]
    fn zero_prefix_len_leaves_trace_unchanged() {
        let plain = generate_trace(&TraceConfig::default());
        let inert = generate_trace(&TraceConfig {
            share_prob: 0.9,
            ..Default::default()
        });
        assert_eq!(plain, inert);
    }

    #[test]
    fn shared_prefix_mix_shares_one_prefix_across_requests() {
        let cfg = TraceConfig {
            count: 100,
            ..TraceConfig::shared_prefix_mix(5)
        };
        let trace = generate_trace(&cfg);
        let shared: Vec<&Request> = trace
            .iter()
            .filter(|r| r.prompt.len() > cfg.prompt_len.1)
            .collect();
        // share_prob 0.75 over 100 requests: both kinds present
        assert!(shared.len() > 50, "only {} shared", shared.len());
        assert!(shared.len() < 100, "every request shared");
        let prefix = &shared[0].prompt[..cfg.prefix_len];
        for r in &shared {
            assert_eq!(&r.prompt[..cfg.prefix_len], prefix);
            let tail = r.prompt.len() - cfg.prefix_len;
            assert!(
                (cfg.prompt_len.0..=cfg.prompt_len.1).contains(&tail),
                "tail {tail}"
            );
        }
        // unshared prompts do NOT begin with the prefix-length stem
        assert!(trace
            .iter()
            .any(|r| r.prompt.len() <= cfg.prompt_len.1
                && !r.prompt.starts_with(prefix)));
    }

    #[test]
    fn long_context_mix_skews_long_prompts_short_targets() {
        let cfg = TraceConfig::long_context_mix(3);
        let trace = generate_trace(&cfg);
        assert_eq!(trace.len(), cfg.count);
        for r in &trace {
            assert!((48..=96).contains(&r.prompt.len()));
            assert!((4..=8).contains(&r.target_len));
        }
    }

    /// Different seeds must generate different traces (the generator
    /// actually consumes its seed).
    #[test]
    fn different_seeds_differ() {
        let a = generate_trace(&TraceConfig {
            seed: 1,
            ..Default::default()
        });
        let b = generate_trace(&TraceConfig {
            seed: 2,
            ..Default::default()
        });
        assert_ne!(a, b);
    }

    #[test]
    fn lockstep_trace_is_uniform_and_simultaneous() {
        let t = lockstep_trace(5, 3, 8, 100, 9);
        assert_eq!(t.len(), 5);
        assert!(t.iter().all(|r| r.arrival_s == 0.0));
        assert!(t.iter().all(|r| r.prompt.len() == 3 && r.target_len == 8));
        assert_eq!(t[2].id, 2);
        assert_ne!(t[0].prompt, t[1].prompt);
    }
}
