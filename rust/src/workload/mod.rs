//! Workload generation: synthetic request traces for the serving
//! examples and the online-admission experiments (no public production
//! trace is available — DESIGN.md §2).

use crate::util::Rng;

/// One generation request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time offset from trace start, seconds.
    pub arrival_s: f64,
    pub prompt: Vec<i32>,
    /// Tokens to generate.
    pub target_len: usize,
}

/// Trace generator: Poisson arrivals, uniform prompt lengths, fixed or
/// jittered target lengths.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    pub seed: u64,
    /// Mean arrivals per second.
    pub rate: f64,
    pub prompt_len: (usize, usize),
    pub target_len: (usize, usize),
    pub vocab: usize,
    pub count: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 1,
            rate: 16.0,
            prompt_len: (4, 16),
            target_len: (32, 64),
            vocab: 256,
            count: 64,
        }
    }
}

pub fn generate_trace(cfg: &TraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0;
    (0..cfg.count as u64)
        .map(|id| {
            t += rng.exponential(cfg.rate);
            let plen = rng.range_usize(cfg.prompt_len.0, cfg.prompt_len.1 + 1);
            let tlen = rng.range_usize(cfg.target_len.0, cfg.target_len.1 + 1);
            Request {
                id,
                arrival_s: t,
                prompt: (0..plen)
                    .map(|_| rng.range_usize(0, cfg.vocab) as i32)
                    .collect(),
                target_len: tlen,
            }
        })
        .collect()
}

/// Fixed-shape batch workload (the paper's §6 throughput benchmark:
/// short prompt, generate to a fixed total length).
pub fn fixed_batch(
    batch: usize,
    prompt_len: usize,
    vocab: usize,
    seed: u64,
) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    (0..batch)
        .map(|_| {
            (0..prompt_len)
                .map(|_| rng.range_usize(0, vocab) as i32)
                .collect()
        })
        .collect()
}

/// Degenerate trace for lockstep tests: `count` equal-shape requests
/// all arriving at t = 0 (continuous batching over this trace must be
/// bit-identical to a fixed-batch `generate` run).
pub fn lockstep_trace(
    count: usize,
    prompt_len: usize,
    target_len: usize,
    vocab: usize,
    seed: u64,
) -> Vec<Request> {
    fixed_batch(count, prompt_len, vocab, seed)
        .into_iter()
        .enumerate()
        .map(|(i, prompt)| Request {
            id: i as u64,
            arrival_s: 0.0,
            prompt,
            target_len,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_ordered() {
        let cfg = TraceConfig::default();
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
        assert_eq!(a.len(), cfg.count);
    }

    #[test]
    fn lengths_respect_bounds() {
        let cfg = TraceConfig {
            prompt_len: (3, 5),
            target_len: (10, 12),
            ..Default::default()
        };
        for r in generate_trace(&cfg) {
            assert!((3..=5).contains(&r.prompt.len()));
            assert!((10..=12).contains(&r.target_len));
            assert!(r.prompt.iter().all(|&t| (t as usize) < cfg.vocab));
        }
    }

    #[test]
    fn arrival_rate_roughly_matches() {
        let cfg = TraceConfig {
            rate: 100.0,
            count: 2000,
            ..Default::default()
        };
        let trace = generate_trace(&cfg);
        let span = trace.last().unwrap().arrival_s;
        let rate = cfg.count as f64 / span;
        assert!((rate / cfg.rate - 1.0).abs() < 0.15, "rate {rate}");
    }

    #[test]
    fn fixed_batch_shapes() {
        let b = fixed_batch(4, 7, 100, 3);
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|p| p.len() == 7));
        assert_ne!(b[0], b[1]); // prompts differ
    }

    /// The MEAN INTER-ARRIVAL GAP itself (not just count/span) matches
    /// 1/rate, and the gaps are genuinely exponential-ish: strictly
    /// positive with substantial spread (a constant-gap generator would
    /// fail the variance floor).
    #[test]
    fn mean_inter_arrival_matches_inverse_rate() {
        let cfg = TraceConfig {
            rate: 50.0,
            count: 4000,
            seed: 7,
            ..Default::default()
        };
        let trace = generate_trace(&cfg);
        let gaps: Vec<f64> = std::iter::once(trace[0].arrival_s)
            .chain(trace.windows(2).map(|w| w[1].arrival_s - w[0].arrival_s))
            .collect();
        assert!(gaps.iter().all(|&g| g >= 0.0));
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let want = 1.0 / cfg.rate;
        assert!(
            (mean / want - 1.0).abs() < 0.1,
            "mean gap {mean} vs 1/rate {want}"
        );
        // exponential: std ≈ mean (coefficient of variation ≈ 1)
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
            / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.15, "coefficient of variation {cv}");
    }

    /// Different seeds must generate different traces (the generator
    /// actually consumes its seed).
    #[test]
    fn different_seeds_differ() {
        let a = generate_trace(&TraceConfig {
            seed: 1,
            ..Default::default()
        });
        let b = generate_trace(&TraceConfig {
            seed: 2,
            ..Default::default()
        });
        assert_ne!(a, b);
    }

    #[test]
    fn lockstep_trace_is_uniform_and_simultaneous() {
        let t = lockstep_trace(5, 3, 8, 100, 9);
        assert_eq!(t.len(), 5);
        assert!(t.iter().all(|r| r.arrival_s == 0.0));
        assert!(t.iter().all(|r| r.prompt.len() == 3 && r.target_len == 8));
        assert_eq!(t[2].id, 2);
        assert_ne!(t[0].prompt, t[1].prompt);
    }
}
