//! `rnode` — a standalone R-worker host process.
//!
//! Binds a TCP listener and serves one R-socket per accepted
//! connection (`net::serve_listener`): the remote end of FastDecode's
//! S↔R boundary, letting the KV-bound R-Part run on CPUs of OTHER
//! machines (paper abstract / §4 — aggregated memory capacity and
//! compute of CPUs across multiple nodes).
//!
//! The node is dimensionless at startup: every connection begins with
//! a `Configure` frame that provisions its `SocketCache` (heads, head
//! dim, layers, KV capacity, cache precision, wire mode), so one rnode
//! binary serves any model the client drives.
//!
//! Usage:
//!   rnode [--listen HOST:PORT]
//!
//! `--listen` defaults to `127.0.0.1:0` (ephemeral port). The resolved
//! address is announced on stdout as `rnode listening on HOST:PORT` —
//! machine-readable, parsed by `tests/net_remote.rs` and the
//! `fig13_scalability --tcp` sweep to discover ephemeral ports.

use anyhow::{bail, Result};

fn main() {
    if let Err(e) = run() {
        eprintln!("rnode: error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut listen = "127.0.0.1:0".to_string();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--listen" => {
                i += 1;
                match args.get(i) {
                    Some(a) => listen = a.clone(),
                    None => bail!("--listen needs HOST:PORT"),
                }
            }
            "--help" | "-h" => {
                println!(
                    "rnode — FastDecode remote R-worker host\n\n\
                     USAGE: rnode [--listen HOST:PORT]\n\n\
                     Serves one R-socket per TCP connection; each \
                     connection self-provisions via its Configure frame. \
                     Announces `rnode listening on HOST:PORT` on stdout."
                );
                return Ok(());
            }
            other => bail!("unknown argument {other:?} (see --help)"),
        }
        i += 1;
    }
    fastdecode::net::run_rnode(listen.as_str())
}
