//! CI gate for benchmark artifacts: validate each `BENCH_*.json` path
//! on the command line against the `bench::snapshot` schema, and each
//! path following `--chrome-trace` against the Chrome trace-event
//! invariants (`obs::validate_chrome_trace_file`: parses, ≥ N
//! `thread_name` tracks, finite non-negative timestamps, per-track
//! monotone span completion). `--min-tracks N` (before the trace paths
//! it applies to) sets the track floor — CI passes the node count of
//! the fig13 TCP run plus its local tracks. Exits non-zero (with a
//! message per offending file) on any missing, empty or malformed
//! artifact.

use std::path::PathBuf;
use std::process::ExitCode;

use fastdecode::bench::snapshot;
use fastdecode::obs::validate_chrome_trace_file;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!(
            "usage: bench_validate <BENCH_*.json>... \
             [--min-tracks <n>] [--chrome-trace <TRACE_*.json>...]"
        );
        return ExitCode::FAILURE;
    }
    let mut failed = false;
    let mut checked = 0usize;
    let mut min_tracks = 1usize;
    let mut chrome = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--min-tracks" => {
                match args.get(i + 1).and_then(|v| v.parse::<usize>().ok()) {
                    Some(n) => min_tracks = n,
                    None => {
                        eprintln!("--min-tracks needs a number");
                        return ExitCode::FAILURE;
                    }
                }
                i += 2;
            }
            "--chrome-trace" => {
                chrome = true;
                i += 1;
            }
            p => {
                let path = PathBuf::from(p);
                let res = if chrome {
                    validate_chrome_trace_file(&path, min_tracks)
                } else {
                    snapshot::validate_file(&path)
                };
                match res {
                    Ok(()) => println!("OK {}", path.display()),
                    Err(e) => {
                        eprintln!("FAIL {}: {e:#}", path.display());
                        failed = true;
                    }
                }
                checked += 1;
                i += 1;
            }
        }
    }
    if checked == 0 {
        eprintln!("bench_validate: no artifact paths given");
        return ExitCode::FAILURE;
    }
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
