//! CI gate for benchmark artifacts: validate each `BENCH_*.json` path
//! on the command line against the `bench::snapshot` schema, and each
//! path following `--chrome-trace` against the Chrome trace-event
//! invariants (`obs::validate_chrome_trace_file`: parses, ≥ N
//! `thread_name` tracks, finite non-negative timestamps, per-track
//! monotone span completion). `--min-tracks N` (before the trace paths
//! it applies to) sets the track floor — CI passes the node count of
//! the fig13 TCP run plus its local tracks. Exits non-zero (with a
//! message per offending file) on any missing, empty or malformed
//! artifact.
//!
//! Two further gates:
//!
//! * `--compare <baseline.json>` additionally runs every snapshot path
//!   through the noise-aware perf-regression gate (`bench::compare`)
//!   against the checked-in baseline: throughput below the floor ratio
//!   or p99 above the ceiling ratio FAILS; a snapshot with no baseline
//!   entry WARNs (new benches land before their baseline does).
//! * paths following `--cluster` are validated as `fdtop --once
//!   --json` cluster documents (`net::monitor::validate_cluster_file`)
//!   — the schema gate CI runs over the live-metrics smoke step.

use std::path::PathBuf;
use std::process::ExitCode;

use fastdecode::bench::compare::{
    load_baseline, Baseline, CompareOutcome,
};
use fastdecode::bench::snapshot;
use fastdecode::net::monitor::validate_cluster_file;
use fastdecode::obs::validate_chrome_trace_file;

enum Mode {
    Snapshot,
    Chrome,
    Cluster,
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!(
            "usage: bench_validate [--compare <baseline.json>] \
             <BENCH_*.json>... [--min-tracks <n>] \
             [--chrome-trace <TRACE_*.json>...] \
             [--cluster <fdtop.json>...]"
        );
        return ExitCode::FAILURE;
    }
    let mut failed = false;
    let mut checked = 0usize;
    let mut min_tracks = 1usize;
    let mut mode = Mode::Snapshot;
    let mut baseline: Option<Baseline> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--min-tracks" => {
                match args.get(i + 1).and_then(|v| v.parse::<usize>().ok()) {
                    Some(n) => min_tracks = n,
                    None => {
                        eprintln!("--min-tracks needs a number");
                        return ExitCode::FAILURE;
                    }
                }
                i += 2;
            }
            "--compare" => {
                let Some(path) = args.get(i + 1) else {
                    eprintln!("--compare needs a baseline path");
                    return ExitCode::FAILURE;
                };
                match load_baseline(&PathBuf::from(path)) {
                    Ok(b) => baseline = Some(b),
                    Err(e) => {
                        eprintln!("FAIL {path}: {e:#}");
                        return ExitCode::FAILURE;
                    }
                }
                i += 2;
            }
            "--chrome-trace" => {
                mode = Mode::Chrome;
                i += 1;
            }
            "--cluster" => {
                mode = Mode::Cluster;
                i += 1;
            }
            p => {
                let path = PathBuf::from(p);
                let res = match mode {
                    Mode::Chrome => {
                        validate_chrome_trace_file(&path, min_tracks)
                    }
                    Mode::Cluster => validate_cluster_file(&path),
                    Mode::Snapshot => snapshot::validate_file(&path),
                };
                match res {
                    Ok(()) => println!("OK {}", path.display()),
                    Err(e) => {
                        eprintln!("FAIL {}: {e:#}", path.display());
                        failed = true;
                    }
                }
                if let (Mode::Snapshot, Some(base)) = (&mode, &baseline) {
                    match fastdecode::bench::compare::compare_file(
                        &path, base,
                    ) {
                        Ok(CompareOutcome::Pass {
                            name,
                            tok_ratio,
                            p99_ratio,
                        }) => println!(
                            "COMPARE ok {name}: tok {tok_ratio:.2}x, p99 \
                             {p99_ratio:.2}x of baseline"
                        ),
                        Ok(CompareOutcome::NoBaseline { name }) => {
                            println!(
                                "COMPARE warn {name}: no baseline entry \
                                 (add one to pin this bench)"
                            );
                        }
                        Ok(CompareOutcome::Fail { name, reasons }) => {
                            for r in &reasons {
                                eprintln!("COMPARE FAIL {name}: {r}");
                            }
                            failed = true;
                        }
                        Err(e) => {
                            eprintln!(
                                "COMPARE FAIL {}: {e:#}",
                                path.display()
                            );
                            failed = true;
                        }
                    }
                }
                checked += 1;
                i += 1;
            }
        }
    }
    if checked == 0 {
        eprintln!("bench_validate: no artifact paths given");
        return ExitCode::FAILURE;
    }
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
