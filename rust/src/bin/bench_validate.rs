//! CI gate for benchmark snapshots: validate each `BENCH_*.json` path
//! on the command line against the `bench::snapshot` schema. Exits
//! non-zero (with a message per offending file) on any missing, empty
//! or malformed snapshot.

use std::path::PathBuf;
use std::process::ExitCode;

use fastdecode::bench::snapshot;

fn main() -> ExitCode {
    let paths: Vec<PathBuf> =
        std::env::args_os().skip(1).map(PathBuf::from).collect();
    if paths.is_empty() {
        eprintln!("usage: bench_validate <BENCH_*.json>...");
        return ExitCode::FAILURE;
    }
    let mut failed = false;
    for path in &paths {
        match snapshot::validate_file(path) {
            Ok(()) => println!("OK {}", path.display()),
            Err(e) => {
                eprintln!("FAIL {}: {e:#}", path.display());
                failed = true;
            }
        }
    }
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
