//! `fdtop` — live per-node dashboard for a running rnode cluster.
//!
//! Each tick opens a fresh monitor connection to every address and
//! asks for its `NodeStats` self-report (`net::monitor`): no
//! `Configure` handshake, no interference with the serving
//! connections. A node that cannot be reached renders as a DEAD row
//! with the root cause — the dashboard keeps running on the
//! survivors, which is exactly when a dashboard matters.
//!
//! Usage:
//!   fdtop [--interval SECS] [--once] [--json] ADDR...
//!
//! * default: clear-screen table every `--interval` seconds (2.0 by
//!   default); the TOK/S column uses between-poll deltas after the
//!   first tick (cumulative rows/uptime on the first).
//! * `--once`: poll once, print, exit 0 (dead nodes do NOT fail the
//!   exit code — the row reports them; scripts check `alive`).
//! * `--json`: emit the `net::monitor::cluster_json` document instead
//!   of the table — the scripting/CI surface, schema-validated by
//!   `bench_validate --cluster`.

use anyhow::{bail, Result};

use fastdecode::net::monitor::{cluster_json, poll_cluster, rate_between, render_table};
use fastdecode::net::NodeStatsReport;

struct Opts {
    interval_s: f64,
    once: bool,
    json: bool,
    addrs: Vec<String>,
}

fn parse_args(args: &[String]) -> Result<Option<Opts>> {
    let mut opts = Opts {
        interval_s: 2.0,
        once: false,
        json: false,
        addrs: Vec::new(),
    };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--interval" => {
                i += 1;
                match args.get(i).and_then(|v| v.parse::<f64>().ok()) {
                    Some(s) if s > 0.0 && s.is_finite() => {
                        opts.interval_s = s
                    }
                    _ => bail!("--interval needs a positive number of seconds"),
                }
            }
            "--once" => opts.once = true,
            "--json" => opts.json = true,
            "--help" | "-h" => {
                println!(
                    "fdtop — live per-node dashboard for rnode clusters\n\n\
                     USAGE: fdtop [--interval SECS] [--once] [--json] \
                     ADDR...\n\n\
                     Polls each rnode's NodeStats self-report over a fresh \
                     monitor connection per tick. Dead nodes render as DEAD \
                     rows (alive:false in --json) instead of aborting."
                );
                return Ok(None);
            }
            flag if flag.starts_with('-') => {
                bail!("unknown flag {flag:?} (see --help)")
            }
            addr => opts.addrs.push(addr.to_string()),
        }
        i += 1;
    }
    if opts.addrs.is_empty() {
        bail!("no node addresses given (see --help)");
    }
    Ok(Some(opts))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match parse_args(&args).and_then(|opts| match opts {
        Some(opts) => run(&opts),
        None => Ok(()),
    }) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("fdtop: error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(opts: &Opts) -> Result<()> {
    let mut prev: Vec<Option<NodeStatsReport>> = vec![None; opts.addrs.len()];
    loop {
        let rows = poll_cluster(&opts.addrs);
        if opts.json {
            println!("{}", cluster_json(&rows).render());
        } else {
            // between-poll deltas once a node has two samples
            let rates: Vec<Option<f64>> = rows
                .iter()
                .enumerate()
                .map(|(i, row)| match (&prev[i], &row.report) {
                    (Some(p), Some(c)) => rate_between(p, c),
                    _ => None,
                })
                .collect();
            if !opts.once {
                // ANSI clear-screen + home, like top(1)
                print!("\x1b[2J\x1b[H");
            }
            print!("{}", render_table(&rows, &rates));
        }
        if opts.once {
            return Ok(());
        }
        for (i, row) in rows.into_iter().enumerate() {
            if let Some(r) = row.report {
                prev[i] = Some(r);
            }
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(opts.interval_s));
    }
}
