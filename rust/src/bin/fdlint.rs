//! CI gate binary for the fdlint project-invariant analyzer.
//!
//! ```text
//! cargo run --release --bin fdlint                    # gate rust/src
//! cargo run --release --bin fdlint -- --update-baseline
//! cargo run --release --bin fdlint -- --root path/src --baseline path/b
//! ```
//!
//! Exit codes: 0 clean, 1 gate failure, 2 usage/IO error.

use std::path::PathBuf;
use std::process::ExitCode;

use fastdecode::analysis::{
    analyze, baseline_of, collect_sources, compare, format_baseline,
    parse_baseline, Baseline,
};

const USAGE: &str = "usage: fdlint [--root <dir>] [--baseline <file>] \
                     [--update-baseline]";

struct Args {
    root: PathBuf,
    baseline: PathBuf,
    update: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        root: PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src")),
        baseline: PathBuf::from(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/fdlint.baseline"
        )),
        update: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--root" => {
                let v = it.next().ok_or("--root needs a value")?;
                args.root = PathBuf::from(v);
            }
            "--baseline" => {
                let v = it.next().ok_or("--baseline needs a value")?;
                args.baseline = PathBuf::from(v);
            }
            "--update-baseline" => args.update = true,
            "-h" | "--help" => return Err(USAGE.to_string()),
            other => return Err(format!("unknown argument {other:?}\n{USAGE}")),
        }
    }
    Ok(args)
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };

    let files = match collect_sources(&args.root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("fdlint: failed to collect sources: {e:#}");
            return ExitCode::from(2);
        }
    };
    let analysis = analyze(&files);
    let current = baseline_of(&analysis.violations);

    if args.update {
        let text = format_baseline(&current);
        if let Err(e) = std::fs::write(&args.baseline, text) {
            eprintln!(
                "fdlint: failed to write {}: {e}",
                args.baseline.display()
            );
            return ExitCode::from(2);
        }
        println!(
            "fdlint: baseline rewritten with {} grandfathered violation(s) \
             across {} (rule, file) entries",
            analysis.violations.len(),
            current.len()
        );
        return ExitCode::SUCCESS;
    }

    let grandfathered: Baseline = match std::fs::read_to_string(&args.baseline)
    {
        Ok(text) => match parse_baseline(&text) {
            Ok(b) => b,
            Err(e) => {
                eprintln!(
                    "fdlint: bad baseline {}: {e:#}",
                    args.baseline.display()
                );
                return ExitCode::from(2);
            }
        },
        // a missing baseline means nothing is grandfathered
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Baseline::new(),
        Err(e) => {
            eprintln!(
                "fdlint: failed to read {}: {e}",
                args.baseline.display()
            );
            return ExitCode::from(2);
        }
    };

    let failures = compare(&current, &grandfathered, &analysis.violations);
    if failures.is_empty() {
        println!(
            "fdlint: OK — {} file(s), {} suppressed by allow, {} \
             grandfathered by baseline",
            analysis.files,
            analysis.allowed,
            analysis.violations.len()
        );
        ExitCode::SUCCESS
    } else {
        for f in &failures {
            eprintln!("fdlint: {f}");
        }
        eprintln!("fdlint: FAILED ({} finding(s))", failures.len());
        ExitCode::FAILURE
    }
}
