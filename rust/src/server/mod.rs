//! Minimal serving front-end: an admission queue driven by Algorithm 1
//! feeding the engine in micro-batches (the online-serving story of
//! §4.2's "extra benefit": a request waits at most F steps, not S).
//!
//! This is deliberately a library-level loop, not a network server —
//! the offline environment has no async runtime; the public API is
//! [`AdmissionQueue`] + [`ServeReport`], exercised by examples/serve_e2e.

use std::collections::VecDeque;

use crate::sched::LoadControl;
use crate::workload::Request;

/// Admission decision state over a virtual step clock.
pub struct AdmissionQueue {
    pub w_lim: usize,
    pub micro_size: usize,
    pub seq_len: usize,
    waiting: VecDeque<Request>,
    ctl: LoadControl,
    /// (start_step, requests) pairs already admitted but not started.
    pub scheduled: VecDeque<(usize, Vec<Request>)>,
}

impl AdmissionQueue {
    pub fn new(w_lim: usize, micro_size: usize, seq_len: usize) -> Self {
        assert!(micro_size > 0 && seq_len > 0);
        AdmissionQueue {
            w_lim,
            micro_size,
            seq_len,
            waiting: VecDeque::new(),
            ctl: LoadControl::new(),
            scheduled: VecDeque::new(),
        }
    }

    pub fn push(&mut self, r: Request) {
        self.waiting.push_back(r);
    }

    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Try to admit full micro-batches at `now`; returns batches whose
    /// start step equals `now` (the engine starts them this step).
    pub fn admit(&mut self, now: usize) -> Vec<Vec<Request>> {
        self.ctl.retire_before(now);
        while self.waiting.len() >= self.micro_size {
            match self.ctl.earliest_start(
                now,
                self.micro_size,
                self.seq_len,
                self.w_lim,
            ) {
                Some(start) => {
                    let batch: Vec<Request> = (0..self.micro_size)
                        .map(|_| self.waiting.pop_front().unwrap())
                        .collect();
                    self.ctl.add(start, self.micro_size, self.seq_len);
                    self.scheduled.push_back((start, batch));
                }
                None => break,
            }
        }
        let mut due = Vec::new();
        while let Some(&(start, _)) = self.scheduled.front() {
            if start <= now {
                due.push(self.scheduled.pop_front().unwrap().1);
            } else {
                break;
            }
        }
        due
    }

    /// Current aggregate-context commitment at `step`.
    pub fn load_at(&self, step: usize) -> usize {
        self.ctl.load_at(step)
    }
}

/// Summary of a serving run.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeReport {
    pub requests: usize,
    pub tokens: u64,
    pub elapsed_s: f64,
    pub mean_wait_steps: f64,
}

impl ServeReport {
    pub fn throughput(&self) -> f64 {
        if self.elapsed_s == 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.elapsed_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            prompt: vec![1],
            target_len: 8,
        }
    }

    #[test]
    fn admits_in_micro_batches() {
        let mut q = AdmissionQueue::new(1000, 2, 8);
        q.push(req(0));
        assert!(q.admit(0).is_empty()); // below micro size
        q.push(req(1));
        let due = q.admit(0);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].len(), 2);
        assert_eq!(q.waiting(), 0);
    }

    #[test]
    fn limit_defers_admission() {
        // w_lim fits exactly one micro-batch (2 × 8 = 16)
        let mut q = AdmissionQueue::new(16, 2, 8);
        for i in 0..4 {
            q.push(req(i));
        }
        let now0 = q.admit(0);
        assert_eq!(now0.len(), 1, "only one batch fits at step 0");
        // the second batch was scheduled for later, not dropped
        assert_eq!(q.scheduled.len(), 1);
        let later = q.scheduled.front().unwrap().0;
        assert!(later >= 8, "second batch must wait for the first to end");
        // stepping to that time releases it
        let due = q.admit(later);
        assert_eq!(due.len(), 1);
    }

    #[test]
    fn load_accounting_tracks_admissions() {
        let mut q = AdmissionQueue::new(1000, 2, 8);
        q.push(req(0));
        q.push(req(1));
        q.admit(0);
        assert_eq!(q.load_at(0), 2);
        assert_eq!(q.load_at(7), 16);
        assert_eq!(q.load_at(8), 0);
    }
}
