//! Wave-based serving front-end: the LEGACY micro-batch admission
//! queue, kept for the Algorithm-1 wave experiments.
//!
//! # Where requests actually live now
//!
//! The request lifecycle — *arrival → admission → prefill → decode
//! slots → retire* — is owned by the [`crate::serve`] subsystem:
//! open-loop traces replay on a virtual step clock, a pluggable
//! [`crate::serve::AdmissionPolicy`] admits requests into decode slots
//! under W_lim, prompts prefill in one batched multi-row pass, and
//! finished sequences free their KV and their slot independently
//! (continuous batching, per-request TTFT/ITL/E2E metrics in a
//! [`ServeReport`]).
//!
//! [`AdmissionQueue`] predates that subsystem and stays useful where
//! requests are served in uniform micro-batch WAVES of exactly
//! `micro_size` equal-length jobs (§4.2's "a request waits at most F
//! steps, not S"): it schedules whole waves onto the step clock via
//! [`crate::sched::LoadControl::earliest_start`]. Because `admit` only
//! forms full waves, a trace tail smaller than `micro_size` would wait
//! forever — call [`AdmissionQueue::close`] once the trace is exhausted
//! and the final partial wave drains through the same load-control
//! path.
//!
//! This is deliberately a library-level loop, not a network server —
//! the offline environment has no async runtime; the public API is
//! exercised by `examples/serve_e2e.rs` (which now drives
//! `serve::ServeEngine`) and the tests below.

use std::collections::VecDeque;

use crate::sched::LoadControl;
use crate::workload::Request;

pub use crate::serve::ServeReport;

/// Admission decision state over a virtual step clock.
pub struct AdmissionQueue {
    pub w_lim: usize,
    pub micro_size: usize,
    pub seq_len: usize,
    waiting: VecDeque<Request>,
    ctl: LoadControl,
    /// No more arrivals: the final partial wave may drain.
    closed: bool,
    /// (start_step, requests) pairs already admitted but not started.
    pub scheduled: VecDeque<(usize, Vec<Request>)>,
}

impl AdmissionQueue {
    pub fn new(w_lim: usize, micro_size: usize, seq_len: usize) -> Self {
        assert!(micro_size > 0 && seq_len > 0);
        AdmissionQueue {
            w_lim,
            micro_size,
            seq_len,
            waiting: VecDeque::new(),
            ctl: LoadControl::new(),
            closed: false,
            scheduled: VecDeque::new(),
        }
    }

    pub fn push(&mut self, r: Request) {
        assert!(!self.closed, "push after close");
        self.waiting.push_back(r);
    }

    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Declare the trace exhausted: from the next `admit` on, a final
    /// partial wave (fewer than `micro_size` requests) is admitted
    /// through the same load-control path instead of starving forever.
    pub fn close(&mut self) {
        self.closed = true;
    }

    /// Try to admit micro-batches at `now`; returns batches whose start
    /// step has come (the engine starts them this step). Full waves
    /// only, unless [`AdmissionQueue::close`] was called — then the
    /// final partial wave drains too.
    pub fn admit(&mut self, now: usize) -> Vec<Vec<Request>> {
        self.ctl.retire_before(now);
        while self.waiting.len() >= self.micro_size {
            if !self.schedule_wave(now, self.micro_size) {
                break;
            }
        }
        // the partial tail: strictly fewer than micro_size requests can
        // never form a full wave — drain them once the queue is closed
        if self.closed && !self.waiting.is_empty() {
            let m = self.waiting.len().min(self.micro_size);
            self.schedule_wave(now, m);
        }
        // collect due waves; a partial tail may have been scheduled
        // EARLIER than a previously deferred full wave, so scan the
        // whole list rather than popping a sorted front
        let mut due = Vec::new();
        let mut rest = VecDeque::new();
        while let Some((start, batch)) = self.scheduled.pop_front() {
            if start <= now {
                due.push(batch);
            } else {
                rest.push_back((start, batch));
            }
        }
        self.scheduled = rest;
        due
    }

    /// Schedule one wave of `m` requests at its earliest feasible start
    /// ≥ `now`; false if the load controller can never fit it
    /// (m·S > W_lim). Identical shapes make successive waves' starts
    /// monotone, so FIFO wave order emerges from the controller itself.
    fn schedule_wave(&mut self, now: usize, m: usize) -> bool {
        match self.ctl.earliest_start(now, m, self.seq_len, self.w_lim) {
            Some(start) => {
                let batch: Vec<Request> = (0..m)
                    .map(|_| self.waiting.pop_front().expect("m ≤ waiting"))
                    .collect();
                self.ctl.add(start, m, self.seq_len);
                self.scheduled.push_back((start, batch));
                true
            }
            None => false,
        }
    }

    /// Current aggregate-context commitment at `step`.
    pub fn load_at(&self, step: usize) -> usize {
        self.ctl.load_at(step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request {
            id,
            arrival_s: 0.0,
            prompt: vec![1],
            target_len: 8,
        }
    }

    #[test]
    fn admits_in_micro_batches() {
        let mut q = AdmissionQueue::new(1000, 2, 8);
        q.push(req(0));
        assert!(q.admit(0).is_empty()); // below micro size
        q.push(req(1));
        let due = q.admit(0);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].len(), 2);
        assert_eq!(q.waiting(), 0);
    }

    #[test]
    fn limit_defers_admission() {
        // w_lim fits exactly one micro-batch (2 × 8 = 16)
        let mut q = AdmissionQueue::new(16, 2, 8);
        for i in 0..4 {
            q.push(req(i));
        }
        let now0 = q.admit(0);
        assert_eq!(now0.len(), 1, "only one batch fits at step 0");
        // the second batch was scheduled for later, not dropped
        assert_eq!(q.scheduled.len(), 1);
        let later = q.scheduled.front().unwrap().0;
        assert!(later >= 8, "second batch must wait for the first to end");
        // stepping to that time releases it
        let due = q.admit(later);
        assert_eq!(due.len(), 1);
    }

    #[test]
    fn load_accounting_tracks_admissions() {
        let mut q = AdmissionQueue::new(1000, 2, 8);
        q.push(req(0));
        q.push(req(1));
        q.admit(0);
        assert_eq!(q.load_at(0), 2);
        assert_eq!(q.load_at(7), 16);
        assert_eq!(q.load_at(8), 0);
    }

    /// Regression for the tail-starvation bug: requests fewer than
    /// `micro_size` were never admitted (the full-wave loop skipped
    /// them forever). After `close`, the partial tail drains through
    /// the same earliest-start path.
    #[test]
    fn partial_tail_drains_after_close() {
        let mut q = AdmissionQueue::new(1000, 4, 8);
        for i in 0..6 {
            q.push(req(i));
        }
        let due = q.admit(0);
        assert_eq!(due.len(), 1, "one full wave of 4");
        assert_eq!(due[0].len(), 4);
        assert_eq!(q.waiting(), 2);
        // without close, the 2-request tail starves at any step
        assert!(q.admit(50).is_empty());
        assert_eq!(q.waiting(), 2);
        q.close();
        let tail = q.admit(50);
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0].len(), 2, "partial tail admitted");
        assert_eq!(q.waiting(), 0);
    }

    /// The drained tail still honors W_lim: with zero headroom it is
    /// scheduled after the in-flight wave ends, not on top of it.
    #[test]
    fn partial_tail_respects_load_limit() {
        // w_lim fits exactly one full wave (2 × 8 = 16)
        let mut q = AdmissionQueue::new(16, 2, 8);
        for i in 0..3 {
            q.push(req(i));
        }
        assert_eq!(q.admit(0).len(), 1); // full wave in flight
        q.close();
        assert!(q.admit(0).is_empty(), "tail must wait for headroom");
        assert_eq!(q.scheduled.len(), 1);
        let start = q.scheduled.front().unwrap().0;
        assert!(start >= 8, "tail scheduled after the wave ends");
        let tail = q.admit(start);
        assert_eq!(tail.len(), 1);
        assert_eq!(tail[0].len(), 1);
        // and the commitment never exceeded the limit
        for t in 0..=start + 8 {
            assert!(q.load_at(t) <= 16, "load at {t}");
        }
    }
}
