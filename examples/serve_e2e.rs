//! End-to-end serving driver (the DESIGN.md validation workload): a real
//! small model served in batched waves against a synthetic online trace,
//! through the full stack — Algorithm-1 admission, the threaded
//! token-level pipeline (native S-Part thread + Rust R-workers over fp16
//! KV) — reporting latency and throughput.
//!
//! Run: `cargo run --release --example serve_e2e`
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::metrics::Histogram;
use fastdecode::model::{Precision, TINY};
use fastdecode::server::AdmissionQueue;
use fastdecode::workload::{generate_trace, TraceConfig};

fn main() -> anyhow::Result<()> {
    let batch = 8; // wave size
    let gen_steps = 24; // tokens generated per request
    let prompt_len = 4;

    let mut fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            batch,
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: 64,
            ..Default::default()
        },
    )?;

    // A 64-request online trace (Poisson arrivals, fixed shapes so waves
    // batch cleanly — ragged shapes would need continuous batching).
    let trace = generate_trace(&TraceConfig {
        seed: 11,
        rate: 64.0,
        prompt_len: (prompt_len, prompt_len),
        target_len: (gen_steps, gen_steps),
        vocab: TINY.vocab,
        count: 64,
    });
    println!(
        "serving {} requests (prompt {prompt_len}, generate {gen_steps}) \
         in waves of {batch}\n",
        trace.len()
    );

    // Admission: Algorithm 1 with a load limit sized for one wave in
    // flight — requests queue at most one wave (F steps, not S steps).
    let mut queue =
        AdmissionQueue::new(batch * (prompt_len + gen_steps), batch, gen_steps);
    let mut ttft = Histogram::new(); // time to first token (includes queue)
    let mut per_token = Histogram::new();
    let mut served = 0usize;
    let mut tokens = 0u64;
    let t0 = Instant::now();

    let mut pending: Vec<_> = trace.iter().collect();
    let mut virtual_step = 0usize;
    while served < trace.len() {
        // arrivals up to "now" join the queue (we replay the trace as
        // fast as the engine can drain it; arrival_s orders admission)
        while let Some(r) = pending.first() {
            queue.push((*r).clone());
            pending.remove(0);
            if queue.waiting() >= batch {
                break;
            }
        }
        for wave in queue.admit(virtual_step) {
            let wave_start = Instant::now();
            let prompts: Vec<Vec<i32>> =
                wave.iter().map(|r| r.prompt.clone()).collect();
            fd.start_batch((served as u64 + 1) * 1000);
            let result = fd.generate(&prompts, gen_steps)?;
            let dt = wave_start.elapsed().as_secs_f64();

            // first token lands after the prefill + 1 decode step
            let first = result.trace.records.first().map(|r| r.latency_s);
            for _ in &wave {
                ttft.record_secs(first.unwrap_or(dt / gen_steps as f64));
            }
            for r in &result.trace.records {
                per_token.record_secs(r.latency_s);
            }
            served += wave.len();
            tokens += (wave.len() * gen_steps) as u64;
            virtual_step += gen_steps;
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();

    println!("== serve_e2e report ==");
    println!("requests served : {served}");
    println!("tokens generated: {tokens}");
    println!("wall time       : {elapsed:.2} s");
    println!("throughput      : {:.1} tok/s", tokens as f64 / elapsed);
    println!("per-step latency: {}", per_token.summary_ms());
    println!("first-token     : {}", ttft.summary_ms());
    println!(
        "R-worker cache  : {} tokens live after the last wave",
        fd.cache_tokens()
    );
    assert_eq!(served, trace.len(), "every request must be served");
    Ok(())
}
