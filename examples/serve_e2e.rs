//! End-to-end continuous-batching serving (the DESIGN.md validation
//! workload): an open-loop Poisson trace with RAGGED prompt and target
//! lengths served through the full stack — policy-driven admission
//! under W_lim (Algorithm 1 with the batched-prefill init offset), one
//! multi-row causal prefill pass per request, independent decode slots
//! with backfill, per-request TTFT/ITL/E2E percentiles.
//!
//! Run: `cargo run --release --example serve_e2e`
//! (CI runs this as a smoke step.) Results are recorded in
//! EXPERIMENTS.md §End-to-end.

use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::model::{Precision, TINY};
use fastdecode::serve::{
    AdmissionPolicy, Fifo, PrefillMode, ServeConfig, ServeEngine,
    ShortestJobFirst, SlsEarliestStart,
};
use fastdecode::workload::{generate_trace, TraceConfig};

fn main() -> anyhow::Result<()> {
    let slots = 4;
    let trace = generate_trace(&TraceConfig {
        seed: 11,
        rate: 64.0,
        prompt_len: (4, 12),
        target_len: (8, 24),
        vocab: TINY.vocab,
        count: 32,
        ..Default::default()
    });
    let w_lim = 96;
    println!(
        "serving {} open-loop requests (ragged prompts 4–12, targets 8–24) \
         over {slots} slots, W_lim = {w_lim}\n",
        trace.len()
    );

    let policies: Vec<Box<dyn AdmissionPolicy>> = vec![
        Box::new(Fifo),
        Box::new(ShortestJobFirst),
        Box::new(SlsEarliestStart),
    ];
    for policy in policies {
        let fd = FastDecode::new(
            TINY,
            FastDecodeConfig {
                batch: slots,
                sockets: 2,
                precision: Precision::F16,
                capacity_per_seq: 64,
                ..Default::default()
            },
        )?;
        let mut engine = ServeEngine::new(
            fd,
            ServeConfig {
                w_lim,
                steps_per_sec: 200.0,
                prefill: PrefillMode::Batched,
                max_steps: 50_000,
                ..Default::default()
            },
            policy,
        )?;
        let outcome = engine.run(&trace)?;
        println!("== {} ==", outcome.policy);
        println!("{}\n", outcome.report.summary());
        let peak_w = outcome
            .trace
            .records
            .iter()
            .map(|r| r.total_ctx)
            .max()
            .unwrap_or(0);
        println!("peak measured W: {peak_w} (limit {w_lim})\n");
        // the smoke contract CI relies on: every request served, the
        // measured aggregate KV load bounded, percentiles ordered
        assert_eq!(outcome.report.completed, trace.len());
        assert!(peak_w <= w_lim, "measured W {peak_w} exceeded {w_lim}");
        let (p50, p99) = (
            outcome.report.e2e.percentile_us(0.50),
            outcome.report.e2e.percentile_us(0.99),
        );
        assert!(p50 > 0.0 && p50 <= p99, "degenerate E2E percentiles");
    }
    println!("all policies served the full trace under W_lim");
    Ok(())
}
