//! Capacity planner walkthrough — the paper's §4.3 model in practice:
//! given a model, a GPU and a latency budget, how many CPU sockets do I
//! buy, and what batch do I run?
//!
//! Run: `cargo run --release --example capacity_planner`

use fastdecode::bench::Table;
use fastdecode::model::{LLAMA_13B, LLAMA_7B, OPT_175B};
use fastdecode::perfmodel::{
    CpuModel, GpuModel, PlanInput, Planner, A10, EPYC_7452,
};

fn main() {
    let planner =
        Planner::new(GpuModel::new(A10), CpuModel::from_device(EPYC_7452));

    let mut t = Table::new(
        "FastDecode capacity plans (A10 S-worker, Epyc-7452 R-sockets, S=1024)",
        &[
            "model",
            "latency budget",
            "batch B",
            "sockets P",
            "step ms",
            "tok/s",
            "bound",
        ],
    );
    for spec in [LLAMA_7B, LLAMA_13B, OPT_175B] {
        for budget in [None, Some(120.0), Some(400.0)] {
            let r = planner.plan(
                &spec,
                PlanInput {
                    seq_len: 1024,
                    latency_budget: budget,
                    ..Default::default()
                },
            );
            t.row(&[
                spec.name.into(),
                budget
                    .map(|b| format!("{b:.0} s/seq"))
                    .unwrap_or_else(|| "none".into()),
                r.batch.to_string(),
                r.sockets.to_string(),
                format!("{:.1}", r.step_latency * 1e3),
                format!("{:.0}", r.throughput),
                format!("{:?}", r.batch_bound),
            ]);
        }
    }
    t.print();

    println!("observations (matching §4.3):");
    println!("  - tighter latency budgets shrink B (eq. 7), costing throughput;");
    println!("  - larger models (bigger h) need FEWER sockets per GPU (P ∝ 1/h);");
    println!("  - socket count scales with expected sequence length (eq. 11).");

    // sensitivity: sockets vs sequence length for the 7b model
    let mut t2 = Table::new(
        "Sensitivity: minimum sockets vs sequence length (llama7b, B=512)",
        &["seq len S", "sockets P"],
    );
    for s in [128usize, 256, 512, 1024, 2048, 4096] {
        let p = planner.min_sockets(
            &LLAMA_7B,
            512,
            s,
            fastdecode::model::Precision::F16,
        );
        t2.row(&[s.to_string(), p.to_string()]);
    }
    t2.print();
}
