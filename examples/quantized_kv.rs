//! Quantized KV-cache (§5.2): serve the same workload with fp16, int8
//! and int4 caches; compare output agreement, memory, measured R-worker
//! speed, and the planner's socket savings.
//!
//! Run: `cargo run --release --example quantized_kv`

use fastdecode::bench::{Bench, Table};
use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::kvcache::SeqKv;
use fastdecode::model::{Precision, LLAMA_7B, TINY};
use fastdecode::perfmodel::{CpuModel, GpuModel, Planner, A10, EPYC_7452};
use fastdecode::rworker::{attend_one, AttnScratch};
use fastdecode::util::Rng;
use fastdecode::workload::fixed_batch;

fn generate_tokens(prec: Precision) -> anyhow::Result<Vec<Vec<i32>>> {
    let mut fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            batch: 8,
            sockets: 2,
            precision: prec,
            capacity_per_seq: 64,
            weight_seed: 21,
            ..Default::default()
        },
    )?;
    let prompts = fixed_batch(8, 4, TINY.vocab, 13);
    Ok(fd.generate(&prompts, 16)?.tokens)
}

fn agreement(a: &[Vec<i32>], b: &[Vec<i32>]) -> f64 {
    let total: usize = a.iter().map(|s| s.len()).sum();
    let same: usize = a
        .iter()
        .zip(b)
        .map(|(x, y)| x.iter().zip(y).filter(|(p, q)| p == q).count())
        .sum();
    same as f64 / total as f64
}

fn measure_attention(prec: Precision) -> f64 {
    let (h, d, ctx) = (8usize, 128usize, 2048usize);
    let mut kv = SeqKv::new(h, d, ctx, prec);
    let mut rng = Rng::new(5);
    let k = rng.normal_vec(h * d, 0.5);
    let v = rng.normal_vec(h * d, 0.5);
    for _ in 0..ctx {
        kv.append(&k, &v);
    }
    let q = rng.normal_vec(h * d, 0.5);
    let mut o = vec![0.0; h * d];
    let mut scratch = AttnScratch::new(d);
    Bench::quick()
        .measure(|| {
            attend_one(&kv, &q, &mut o, &mut scratch);
            std::hint::black_box(&o);
        })
        .mean_s
}

fn main() -> anyhow::Result<()> {
    let reference = generate_tokens(Precision::F32)?;
    let planner =
        Planner::new(GpuModel::new(A10), CpuModel::from_device(EPYC_7452));
    let f16_lat = measure_attention(Precision::F16);

    let mut t = Table::new(
        "KV-cache precision trade-offs (tiny model e2e + 7b planning)",
        &[
            "precision",
            "token agreement vs f32",
            "KV bytes/token (7b)",
            "R-worker latency (measured)",
            "sockets for 7b/S=1024/B=512",
        ],
    );
    for prec in [
        Precision::F16,
        Precision::Int8,
        Precision::Int4,
    ] {
        let toks = generate_tokens(prec)?;
        let agree = agreement(&reference, &toks);
        let lat = measure_attention(prec);
        let sockets = planner.min_sockets(&LLAMA_7B, 512, 1024, prec);
        t.row(&[
            prec.label().into(),
            format!("{:.1} %", agree * 100.0),
            format!("{} KiB", LLAMA_7B.kv_bytes_per_token(prec) / 1024),
            format!("{:.2} ms ({:.2}x f16)", lat * 1e3, f16_lat / lat),
            sockets.to_string(),
        ]);
    }
    t.print();

    println!("§5.1–5.2 story:");
    println!("  - fp16 is lossless in practice (high token agreement);");
    println!("  - int8 stays close; int4 trades accuracy for 4x less memory");
    println!("    traffic — fewer CPUs for the same GPU (last column).");
    Ok(())
}
