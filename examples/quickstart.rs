//! Quickstart: load the AOT artifacts, build a FastDecode engine on the
//! tiny model, and generate a batch of sequences end-to-end — S-Part on
//! PJRT, R-Part (attention over the fp16 KV-cache) on Rust CPU workers.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::sync::Arc;

use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::model::{Precision, TINY};
use fastdecode::runtime::Engine;
use fastdecode::workload::fixed_batch;

fn main() -> anyhow::Result<()> {
    // 1. Load the compiled HLO graphs (written once by `make artifacts`).
    let engine = Arc::new(Engine::load(fastdecode::artifacts_dir())?);
    println!("PJRT platform: {}", engine.platform());
    println!("artifacts: {}", engine.manifest.artifacts.len());

    // 2. Build the engine: 8-sequence batch, 2 R-worker sockets, fp16 KV.
    let mut fd = FastDecode::new(
        engine,
        TINY,
        FastDecodeConfig {
            batch: 8,
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: 128,
            ..Default::default()
        },
    )?;

    // 3. Generate 24 tokens over 8 random 4-token prompts, greedily.
    let prompts = fixed_batch(8, 4, TINY.vocab, 7);
    let result = fd.generate(&prompts, 24)?;

    println!(
        "\ngenerated {} tokens; per-step latency: {}",
        8 * 24,
        result.step_latency.summary_ms()
    );
    for (i, toks) in result.tokens.iter().enumerate() {
        println!("  seq {i}: prompt {:?} → {:?}", prompts[i], &toks[..8]);
    }
    println!(
        "\nKV-cache now holds {} tokens across 2 sockets (never on the S-worker)",
        fd.cache_tokens()
    );
    Ok(())
}
