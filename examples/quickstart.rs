//! Quickstart: build a FastDecode engine on the tiny model and generate
//! a batch of sequences end-to-end — S-Part on the native S-worker
//! thread, R-Part (attention over the fp16 KV-cache) on Rust CPU worker
//! sockets, double-buffered by the token-level pipeline.
//!
//! Run: `cargo run --release --example quickstart`

use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::model::{Precision, TINY};
use fastdecode::workload::fixed_batch;

fn main() -> anyhow::Result<()> {
    // 1. Build the engine: 8-sequence batch, 2 R-worker sockets, fp16 KV.
    let mut fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            batch: 8,
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: 128,
            ..Default::default()
        },
    )?;
    println!("backend: native S-worker thread + 2 R-socket threads");

    // 2. Generate 24 tokens over 8 random 4-token prompts, greedily.
    let prompts = fixed_batch(8, 4, TINY.vocab, 7);
    let result = fd.generate(&prompts, 24)?;

    println!(
        "\ngenerated {} tokens; per-step latency: {}",
        8 * 24,
        result.step_latency.summary_ms()
    );
    for (i, toks) in result.tokens.iter().enumerate() {
        println!("  seq {i}: prompt {:?} → {:?}", prompts[i], &toks[..8]);
    }
    println!(
        "\nKV-cache now holds {} tokens across 2 sockets (never on the S-worker)",
        fd.cache_tokens()?
    );
    Ok(())
}
