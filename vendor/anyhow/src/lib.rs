//! Minimal API-compatible stand-in for the `anyhow` crate, vendored for
//! the offline build (no crates.io access). Implements the subset this
//! repository uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`]
//! macros, and the [`Context`] extension trait for `Result` and
//! `Option`. Error state is a flat context stack of strings — enough for
//! `{}`, `{:#}` and `{:?}` reporting; no downcasting or backtraces.

use std::fmt;

/// A string-chain error: `stack[0]` is the outermost context, the last
/// element is the root cause.
pub struct Error {
    stack: Vec<String>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error {
            stack: vec![message.to_string()],
        }
    }

    fn from_std(err: &(dyn std::error::Error + 'static)) -> Error {
        let mut stack = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            stack.push(s.to_string());
            source = s.source();
        }
        Error { stack }
    }

    /// Push an outer context frame (what `.context(...)` does).
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.stack.insert(0, context.to_string());
        self
    }

    /// The full cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.stack.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, anyhow-style.
            write!(f, "{}", self.stack.join(": "))
        } else {
            write!(f, "{}", self.stack[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.stack[0])?;
        if self.stack.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, frame) in self.stack[1..].iter().enumerate() {
                write!(f, "\n    {i}: {frame}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: any std error converts into `Error`. Coherent
// with the reflexive `From<T> for T` because `Error` itself does not
// implement `std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::from_std(&err)
    }
}

mod private {
    /// Sealed unification of "a std error" and "already an [`Error`]"
    /// so one `Context` impl covers both (the anyhow ext-trait trick).
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> super::Error {
            super::Error::from_std(&self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// Attach context to errors, for both `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<usize> {
        let n: usize = s.parse().context("not a number")?;
        if n == 0 {
            bail!("zero is not allowed (got {s:?})");
        }
        Ok(n)
    }

    #[test]
    fn ok_path() {
        assert_eq!(parse("7").unwrap(), 7);
    }

    #[test]
    fn context_chain_renders() {
        let e = parse("x").unwrap_err();
        assert_eq!(format!("{e}"), "not a number");
        let alt = format!("{e:#}");
        assert!(alt.starts_with("not a number: "), "{alt}");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn bail_formats() {
        let e = parse("0").unwrap_err();
        assert!(format!("{e}").contains("\"0\""));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let n: i32 = "nope".parse()?;
            Ok(n)
        }
        assert!(inner().is_err());
    }
}
